"""Shared test helpers."""

import pytest


def hypothesis_or_stubs():
    """Import hypothesis if installed; otherwise return stub decorators
    that skip ONLY the property-based tests.

    The old module-level ``pytest.importorskip("hypothesis")`` skipped every
    test in the module — deterministic regression tests included — on any
    host without the dev extra (CI installs it; lean containers don't).
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*_a, **_k):
            def deco(fn):
                return pytest.mark.skip(
                    reason="hypothesis not installed")(fn)
            return deco

        def settings(*_a, **_k):
            return lambda fn: fn

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: None

        return given, settings, _Strategies()

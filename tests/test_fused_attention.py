"""Fused sub-byte decode attention (DESIGN.md §20).

The flash-decoding read in kernels/ulppack_attention walks the stored —
possibly paged — cache in online-softmax groups: scores are computed on
the integer lattice (``scale * (q·u - zp·Σq)``), the running (m, l, acc)
carry replaces the full score row, and the paged variant indexes the
pool straight through the block table, so neither a dequantized KV view
nor the gathered logical view ever materializes.

Covered here: fused-vs-dense numerics for both registered backends
('xla' and 'pallas', the latter interpreted off-TPU) across kv_bits
{0, 8, 4, 2} x {contiguous, paged}; engine-level greedy token identity
against the legacy chunked path (the ``REPRO_FUSED_DECODE`` kill-switch
produces the reference); planner/autotuner plumbing; the
``_chunked_attention`` tail paths the fused route bypasses; and the
tensor-parallel identity on the forced-multi-device `shard` lane.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.kernels import autotune, plan as plan_lib, ulppack_attention
from repro.launch.mesh import make_serving_mesh
from repro.models import attention, lm
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine


def kv_cfg(kv_bits=0, name="stablelm-1.6b", **kw):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False, kv_bits=kv_bits), **kw)


# ---------------------------------------------------------------------------
# Numerics: fused read vs a dense dequantize-everything reference
# ---------------------------------------------------------------------------

def _make_cache(rng, b, s, kvh, hd, kv_bits):
    k = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, kvh, hd)), jnp.float32)
    if kv_bits in (8, 4, 2):
        qk, sk = attention._kv_quantize(k, kv_bits)
        qv, sv = attention._kv_quantize(v, kv_bits)
        return {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    return {"k": k, "v": v}


def _dense_reference(q, cache, valid_len, qpos, kv_bits, hd):
    """Materialize the whole dequantized view; masked softmax; rows with
    nothing visible return exact zero (matching the fused l == 0 guard)."""
    if "k_scale" in cache:
        k = attention._kv_dequantize(cache["k"], cache["k_scale"],
                                     jnp.float32, kv_bits, hd)
        v = attention._kv_dequantize(cache["v"], cache["v_scale"],
                                     jnp.float32, kv_bits, hd)
    else:
        k, v = cache["k"], cache["v"]
    b, s, kvh, _ = k.shape
    _, c, h, _ = q.shape
    qg = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, c, kvh,
                                                      h // kvh, hd)
    scores = jnp.einsum("bckgd,bskd->bckgs", qg, k.astype(jnp.float32))
    pos = jnp.arange(s)
    ok = (pos[None, None, :] < valid_len[:, None, None]) & \
         (pos[None, None, :] <= qpos[:, :, None])
    scores = jnp.where(ok[:, :, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    probs = jnp.where((~jnp.any(ok, axis=-1))[:, :, None, None, None],
                      0.0, probs)
    out = jnp.einsum("bckgs,bskd->bckgd", probs, v.astype(jnp.float32))
    return out.reshape(b, c, h, hd)


@pytest.mark.parametrize("backend", ["xla", "pallas"])
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("kv_bits", [0, 8, 4, 2])
def test_fused_matches_dense_reference(kv_bits, paged, backend):
    rng = np.random.default_rng(kv_bits + 7 * paged)
    b, h, kvh, hd, c = 2, 4, 2, 16, 1
    if paged:
        ps, n_pages = 4, 8
        pool = _make_cache(rng, b * n_pages, ps, kvh, hd, kv_bits)
        bt = jnp.arange(b * n_pages, dtype=jnp.int32).reshape(b, n_pages)
        logical = {kk: vv.reshape(b, ps * n_pages, *vv.shape[2:])
                   for kk, vv in pool.items()}
        cache, s = pool, ps * n_pages
    else:
        s = 32
        cache = _make_cache(rng, b, s, kvh, hd, kv_bits)
        bt, logical = None, cache
    q = jnp.asarray(rng.standard_normal((b, c, h, hd)), jnp.float32)
    valid_len = jnp.asarray([13, 7], jnp.int32)
    qpos = (valid_len[:, None] - c) + jnp.arange(c)[None, :]
    want = _dense_reference(q, logical, valid_len, qpos, kv_bits, hd)
    got = ulppack_attention.fused_decode_attention(
        q, cache, valid_len, qpos, kv_bits=kv_bits, hd=hd,
        block_tables=bt, backend=backend)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_fused_verify_window_and_dead_rows():
    """C > 1 (speculative-verify windows) routes through the fused path
    with per-position causal masking, and a valid_len == 0 row (dead
    engine slot) yields exact zeros instead of a uniform-softmax row."""
    rng = np.random.default_rng(5)
    b, h, kvh, hd, c, s = 2, 4, 2, 16, 3, 32
    cache = _make_cache(rng, b, s, kvh, hd, 2)
    q = jnp.asarray(rng.standard_normal((b, c, h, hd)), jnp.float32)
    valid_len = jnp.asarray([9, 0], jnp.int32)
    qpos = (valid_len[:, None] - c) + jnp.arange(c)[None, :]
    want = _dense_reference(q, cache, valid_len, qpos, 2, hd)
    for backend in ("xla", "pallas"):       # pallas re-routes C != 1
        got = ulppack_attention.fused_decode_attention(
            q, cache, valid_len, qpos, kv_bits=2, hd=hd, backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
    np.testing.assert_array_equal(np.asarray(got[1]), 0.0)


def test_fused_paged_scrambled_block_table():
    """A permuted (non-identity) block table reads the same tokens as the
    equivalently permuted contiguous cache — the in-kernel walk really
    follows the table, not physical order."""
    rng = np.random.default_rng(3)
    b, h, kvh, hd, ps, n_pages = 2, 4, 2, 16, 4, 6
    perm = rng.permutation(b * n_pages)
    pool = _make_cache(rng, b * n_pages, ps, kvh, hd, 4)
    bt = jnp.asarray(perm.reshape(b, n_pages), jnp.int32)
    logical = {kk: vv[perm].reshape(b, ps * n_pages, *vv.shape[2:])
               for kk, vv in pool.items()}
    q = jnp.asarray(rng.standard_normal((b, 1, h, hd)), jnp.float32)
    valid_len = jnp.asarray([ps * n_pages, 11], jnp.int32)
    qpos = valid_len[:, None] - 1
    want = _dense_reference(q, logical, valid_len, qpos, 4, hd)
    for backend in ("xla", "pallas"):
        got = ulppack_attention.fused_decode_attention(
            q, pool, valid_len, qpos, kv_bits=4, hd=hd, block_tables=bt,
            backend=backend)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# Planner + autotuner plumbing
# ---------------------------------------------------------------------------

@pytest.fixture(autouse=True)
def _restore_active_cache():
    autotune.reset_active_cache()
    yield
    autotune.reset_active_cache()


def test_plan_attention_decode_page_rounding_and_budget():
    plan_lib.clear_plan_cache()
    p = plan_lib.plan_attention_decode(2, 256, 8, 4, 64, 2, page_size=16,
                                       backend="xla")
    assert p.block_k % 16 == 0 and p.chunks == p.block_k // 16
    # a starved budget halves block_k but never below one page
    q = plan_lib.plan_attention_decode(2, 256, 8, 4, 64, 2, page_size=16,
                                       backend="xla", vmem_budget=1)
    assert q.block_k == 16 and q.chunks == 1
    r = plan_lib.plan_attention_decode(2, 96, 8, 4, 64, 0, backend="xla")
    assert 1 <= r.block_k <= 96 and r.chunks == 1


def test_plan_attention_decode_consults_tuning_cache():
    cache = autotune.set_active_cache(autotune.TuningCache(device="cpu"))
    key = autotune.attention_decode_key(2, 128, 8, 4, 16, 2, page_size=8,
                                        backend="xla")
    autotune._store(cache, key, {"block_k": 24, "chunks": 3,
                                 "wall_us": 1.0})
    p = plan_lib.plan_attention_decode(2, 128, 8, 4, 16, 2, page_size=8,
                                       backend="xla")
    assert (p.block_k, p.chunks, p.source) == (24, 3, "tuned")
    autotune.reset_active_cache()
    p = plan_lib.plan_attention_decode(2, 128, 8, 4, 16, 2, page_size=8,
                                       backend="xla")
    assert p.source == "heuristic"


@pytest.mark.parametrize("paged", [False, True])
def test_tune_attention_decode_smoke(paged):
    cache = autotune.set_active_cache(autotune.TuningCache(device="cpu"))
    entry = autotune.tune_attention_decode(
        1, 32, 4, 2, 16, kv_bits=2, page_size=8 if paged else None,
        backend="xla", repeats=1)
    for field in ("block_k", "chunks", "wall_us", "heuristic_us",
                  "vmem_bytes", "candidates"):
        assert field in entry, field
    key = autotune.attention_decode_key(1, 32, 4, 2, 16, 2,
                                        page_size=8 if paged else None,
                                        backend="xla")
    assert cache.lookup(key) is entry
    plan = plan_lib.plan_attention_decode(
        1, 32, 4, 2, 16, 2, page_size=8 if paged else None, backend="xla")
    assert plan.source == "tuned" and plan.block_k == entry["block_k"]


# ---------------------------------------------------------------------------
# Engine-level greedy identity (the acceptance bar)
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, *, paged, mesh=None, max_new=4):
    eng = ServingEngine(cfg, params, mesh=mesh, config=EngineConfig(
        max_batch=2, max_len=48, packed=False, prefill_chunk=8,
        paged=paged, page_size=16))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    return {r.uid: tuple(r.output) for r in eng.run_to_completion()}


def _prompts(cfg, seed=11):
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    return [base[:18],
            rng.integers(0, cfg.vocab_size, 7).astype(np.int32),
            base[:20]]


@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("kv_bits", [0, 4, 2])
def test_engine_greedy_identity_fused_vs_legacy(kv_bits, paged):
    """Token-for-token: the fused decode read is invisible in the greedy
    tokens vs the legacy gather + chunked-softmax path (kill-switch off
    path produces the reference; distinct jit memo keys per §20)."""
    cfg = kv_cfg(kv_bits)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg)
    with ulppack_attention.disabled():
        want = _run_engine(cfg, params, prompts, paged=paged)
    got = _run_engine(cfg, params, prompts, paged=paged)
    assert got == want


needs_tp4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices for a model=4 mesh "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.mark.shard
@needs_tp4
def test_engine_greedy_identity_fused_tensor_parallel():
    """model=4 mesh: kv_shard_axis pins the 'xla' (GSPMD-partitionable)
    backend; tokens still match the legacy path on the same mesh."""
    cfg = kv_cfg(4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = _prompts(cfg)
    mesh = make_serving_mesh(4)
    with ulppack_attention.disabled():
        want = _run_engine(cfg, params, prompts, paged=True, mesh=mesh)
    got = _run_engine(cfg, params, prompts, paged=True, mesh=mesh)
    assert got == want


# ---------------------------------------------------------------------------
# Legacy-path tails the fused route bypasses (kept load-bearing for
# prefill, windows, and non-fused fallbacks)
# ---------------------------------------------------------------------------

def _legacy_setup(rng, b, sq, skv, h, kvh, hd, kv_bits):
    q = jnp.asarray(rng.standard_normal((b, sq, h, hd)), jnp.float32)
    cache = _make_cache(rng, b, skv, kvh, hd, kv_bits)
    kv_fn = lambda: (attention._kv_dequantize(cache["k"], cache["k_scale"],
                                              jnp.float32, kv_bits, hd),
                     attention._kv_dequantize(cache["v"], cache["v_scale"],
                                              jnp.float32, kv_bits, hd))
    positions = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))

    def mask_fn(qpos):
        return jnp.arange(skv)[None, None, :] <= qpos[:, :, None]

    return q, kv_fn, mask_fn, positions


def test_chunked_attention_remainder_tail():
    """Sq % chunk != 0 exercises the `rem` tail chunk; result equals the
    single-chunk (chunk >= Sq) evaluation."""
    rng = np.random.default_rng(1)
    b, sq, skv, h, kvh, hd = 2, 7, 12, 4, 2, 16
    q, kv_fn, mask_fn, pos = _legacy_setup(rng, b, sq, skv, h, kvh, hd, 4)
    whole = attention._chunked_attention(q, kv_fn, mask_fn, pos, sq)
    tailed = attention._chunked_attention(q, kv_fn, mask_fn, pos, 3)
    np.testing.assert_allclose(np.asarray(tailed), np.asarray(whole),
                               rtol=1e-5, atol=1e-6)


def test_chunked_attention_gqa_groups_quantized_kv():
    """GQA (H > KVH) with a 2-bit packed cache: grouped einsums agree with
    an explicit per-head evaluation that repeats each kv head."""
    rng = np.random.default_rng(2)
    b, sq, skv, h, kvh, hd = 2, 5, 16, 8, 2, 16
    q, kv_fn, mask_fn, pos = _legacy_setup(rng, b, sq, skv, h, kvh, hd, 2)
    got = attention._chunked_attention(q, kv_fn, mask_fn, pos, 2)
    k, v = kv_fn()
    rep = h // kvh
    kf = jnp.repeat(k, rep, axis=2)
    vf = jnp.repeat(v, rep, axis=2)
    scores = jnp.einsum("bqhd,bshd->bhqs", q * hd ** -0.5, kf)
    scores = jnp.where(mask_fn(pos)[:, None, :, :], scores, -1e30)
    want = jnp.einsum("bhqs,bshd->bqhd", jax.nn.softmax(scores, -1), vf)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)

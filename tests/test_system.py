"""End-to-end behaviour: QAT-train a tiny LM -> loss drops -> checkpoint ->
pack for serving -> decode beats random baseline.  The full product loop on
one CPU device."""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.quant import QuantConfig
from repro.data.pipeline import DataConfig
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.serve.prepare import prepare_serving_params
from repro.train.loop import TrainLoopConfig, Trainer


def test_train_quantize_serve_loop(tmp_path):
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=4, d_ff=128,
        vocab_size=256, param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=3, a_bits=3))
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                          global_batch=8, seed=0)
    loop = TrainLoopConfig(total_steps=80, checkpoint_every=40,
                           checkpoint_dir=str(tmp_path), log_every=10,
                           async_checkpoint=False)
    trainer = Trainer(cfg, loop, data_cfg, seed=0,
                      train_step_kwargs={"peak_lr": 3e-3,
                                         "warmup_steps": 10,
                                         "total_steps": 80})
    state, _ = trainer.run()

    # training made progress (QAT mode, the paper's technique active)
    first, last = trainer.metrics_log[0]["loss"], \
        trainer.metrics_log[-1]["loss"]
    assert last < first - 0.05, (first, last)

    # checkpoint exists and restores
    from repro.train import checkpoint
    assert checkpoint.latest_step(tmp_path) == 80

    # deploy: pack weights, decode with the integer path
    packed = prepare_serving_params(state["params"], cfg)
    decode = jax.jit(steps_lib.make_decode_step(cfg))
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    stream = trainer.data
    batch = stream.batch_at(999)
    tokens = jnp.asarray(batch["tokens"][:2, :10])
    labels = jnp.asarray(batch["labels"][:2, :10])
    nll = []
    for t in range(10):
        logits, caches = decode(packed, caches,
                                {"tokens": tokens[:, t:t + 1]},
                                jnp.int32(t))
        logp = jax.nn.log_softmax(logits[:, :cfg.vocab_size], axis=-1)
        nll.append(-np.asarray(
            jnp.take_along_axis(logp, labels[:, t][:, None], 1)))
    mean_nll = float(np.mean(nll))
    assert mean_nll < np.log(cfg.vocab_size) - 0.05, mean_nll


def test_grad_compression_training_converges(tmp_path):
    """Training WITH int8 gradient compression + error feedback still
    converges (distributed-optimization trick, DESIGN.md §6)."""
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=128, param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    state = steps_lib.make_train_state(params, cfg=cfg,
                                       error_feedback=True)
    step = jax.jit(steps_lib.make_train_step(
        cfg, peak_lr=3e-3, warmup_steps=5, total_steps=60,
        compress_grads=True))
    from repro.data.pipeline import SyntheticLMStream
    stream = SyntheticLMStream(DataConfig(vocab_size=128, seq_len=32,
                                          global_batch=8, seed=1))
    losses = []
    for i in range(60):
        batch = jax.tree.map(jnp.asarray, stream.batch_at(i))
        state, metrics = step(state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.05, (losses[0], losses[-1])

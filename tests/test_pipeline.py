"""Pipeline parallelism: gpipe over a 2-stage forced-host-device mesh,
validated against sequential stage application (subprocess so the 2-device
XLA flag cannot leak into other tests)."""

import os
import subprocess
import sys
import textwrap

import pytest


def subprocess_env():
    """Scrubbed env for hermetic subprocess lowerings, with the operator's
    jax backend pins passed through: without them the child falls into
    backend autodetection, which can hang for minutes (or grab a device)
    on hosts that pin JAX_PLATFORMS — the seed-failing env assumption."""
    env = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root"}
    for var in ("JAX_PLATFORMS", "JAX_PLATFORM_NAME"):
        if var in os.environ:
            env[var] = os.environ[var]
    return env

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    import jax, jax.numpy as jnp, numpy as np
    from repro.parallel.pipeline import gpipe, bubble_fraction

    mesh = jax.make_mesh((2,), ("pod",))
    rng = np.random.default_rng(0)
    d = 16
    # two stages, each y = tanh(x @ w_s)
    w = jnp.asarray(rng.normal(size=(2, d, d)) / np.sqrt(d), jnp.float32)
    xs = jnp.asarray(rng.normal(size=(4, 3, d)), jnp.float32)  # 4 micro x 3

    def stage(params, x):
        return jnp.tanh(x @ params)

    out = gpipe(stage, w, xs, mesh=mesh, axis="pod")

    want = xs
    for s in range(2):
        want = jnp.tanh(want @ w[s])
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)
    assert abs(bubble_fraction(4, 2) - 0.2) < 1e-9
    print("PIPELINE_OK")
""")


@pytest.mark.slow
def test_gpipe_two_stages_subprocess():
    r = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                       text=True, timeout=300, env=subprocess_env())
    assert "PIPELINE_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-2000:])

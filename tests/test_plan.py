"""KernelPlan planner + backend registry + dense weight storage."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import PackSpec
from repro.kernels import ops, ref
from repro.kernels import plan as plan_lib


SPEC = PackSpec(2, 2, jnp.int16.dtype)


class TestPlanner:
    def test_plan_is_memoized_per_signature(self):
        a = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        b = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert a is b
        c = plan_lib.plan_packed_matmul(9, 32, 64, SPEC, backend="xla")
        assert c is not a

    def test_plan_is_hashable_and_frozen(self):
        p = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        hash(p)
        with pytest.raises(Exception):
            p.backend = "pallas"

    def test_resolve_backend(self):
        assert plan_lib.resolve_backend("pallas") == "pallas"
        assert plan_lib.resolve_backend("auto") in ("pallas", "xla")
        with pytest.raises(ValueError):
            plan_lib.resolve_backend("cuda")

    def test_unresolved_backend_rejected_by_plan(self):
        with pytest.raises(ValueError):
            plan_lib.KernelPlan(op="packed_matmul", backend="auto")

    def test_dense_plan_requires_k_full(self):
        with pytest.raises(ValueError):
            plan_lib.KernelPlan(op="packed_matmul", backend="xla",
                                spec=SPEC, weight_store="dense")

    def test_conv_block_h_shrinks_with_budget(self):
        x_shape, w_shape = (1, 256, 256, 16), (7, 7, 16, 32)
        big = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                          padding="VALID", backend="xla")
        small = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                            padding="VALID", backend="xla",
                                            vmem_budget=256 * 1024)
        assert small.block_h < big.block_h
        assert small.vmem_bytes <= 256 * 1024
        tiny = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                           padding="VALID", backend="xla",
                                           vmem_budget=64 * 1024)
        assert tiny.block_h <= small.block_h

    def test_conv_block_h_capped_at_out_h(self):
        p = plan_lib.plan_packed_conv2d((1, 10, 10, 4), (3, 3, 4, 8), SPEC,
                                        padding="VALID", backend="xla")
        assert p.block_h <= 8   # out_h = 10 - 3 + 1

    def test_interpret_defaults_from_device(self):
        """Regression: hand-built plans and direct kernel calls must default
        ``interpret`` from the device (interpreter only off-TPU), not a
        hard-coded True that would silently interpret on TPU."""
        import inspect

        import jax

        from repro.kernels import quant_pack, ulppack_conv2d, ulppack_matmul

        want = jax.default_backend() != "tpu"
        assert plan_lib.default_interpret() == want
        hand_built = plan_lib.KernelPlan(op="int_matmul", backend="xla")
        assert hand_built.interpret == want
        planned = plan_lib.plan_int_matmul(8, 32, 16, backend="xla")
        assert planned.interpret == hand_built.interpret == want
        for fn in (quant_pack.quantize_pack, ulppack_matmul.ulppack_matmul,
                   ulppack_matmul.int_matmul, ulppack_conv2d.ulppack_conv2d,
                   ulppack_conv2d.int_conv2d):
            sig = inspect.signature(fn)
            assert sig.parameters["interpret"].default is None, fn

    def test_describe_reports_tiles(self):
        p = plan_lib.plan_packed_conv2d((1, 64, 64, 16), (7, 7, 16, 32),
                                        SPEC, padding="SAME", backend="xla")
        d = p.describe()
        assert d["op"] == "packed_conv2d"
        assert d["block_h"] >= 1 and d["block_co"] >= 1
        assert 0 < d["vmem_frac"] < 1


class TestRegistry:
    def test_all_public_ops_registered_for_both_backends(self):
        ops_reg = plan_lib.registered_ops()
        for op in ("packed_matmul", "packed_conv2d", "quantize_pack",
                   "int_matmul"):
            assert (op, "pallas") in ops_reg, op
            assert (op, "xla") in ops_reg, op

    def test_unknown_backend_raises(self):
        with pytest.raises(KeyError, match="no backend"):
            plan_lib.get_backend("packed_matmul", "cuda")

    def test_ops_module_has_no_adhoc_resolution(self):
        import inspect

        src = inspect.getsource(ops)
        assert "_resolve" not in src
        assert "def _interpret" not in src

    def test_dispatch_routes_by_plan(self):
        rng = np.random.default_rng(0)
        from repro.core import packing
        q_a = jnp.asarray(rng.integers(0, 4, (5, 40)), jnp.int32)
        q_w = jnp.asarray(rng.integers(0, 4, (40, 7)), jnp.int32)
        ap = packing.pack_activations(q_a, SPEC, -1)
        wp = packing.pack_weights(q_w, SPEC, 0)
        want = ref.matmul_i32_ref(q_a, q_w)
        for backend in ("pallas", "xla"):
            got = ops.packed_matmul(ap, wp, SPEC, backend=backend)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestDenseStorage:
    @pytest.mark.parametrize("w_bits", [1, 2, 4])
    def test_roundtrip(self, w_bits):
        rng = np.random.default_rng(w_bits)
        for k, n in [(1, 1), (5, 3), (64, 16), (97, 8)]:
            q = jnp.asarray(rng.integers(0, 2 ** w_bits, (k, n)), jnp.int32)
            words = ops.dense_store_weights(q, w_bits)
            back = ops.dense_load_weights(words, w_bits, k)
            np.testing.assert_array_equal(np.asarray(back), np.asarray(q))

    @pytest.mark.parametrize("w_bits", [1, 2, 4])
    def test_footprint_is_bit_exact(self, w_bits):
        per = 32 // w_bits
        q = jnp.zeros((per * 8, 64), jnp.int32)
        words = ops.dense_store_weights(q, w_bits)
        assert words.size * 32 == q.size * w_bits

    @pytest.mark.parametrize("w_bits", [1, 2, 4])
    def test_conv_words_roundtrip_via_expand(self, w_bits):
        from repro.kernels.ulppack_conv2d import expand_dense_taps
        from repro.core import packing

        spec = PackSpec(w_bits, 1, jnp.int16.dtype)
        rng = np.random.default_rng(3 * w_bits)
        q_w = jnp.asarray(rng.integers(0, 2 ** w_bits, (3, 3, 10, 5)),
                          jnp.int32)
        words = ops.dense_store_conv_weights(q_w, w_bits)
        lanes = expand_dense_taps(words, spec, 10)
        want = packing.pack_weights(q_w, spec, axis=2)
        np.testing.assert_array_equal(np.asarray(lanes), np.asarray(want))

    def test_prepare_weights_dense_matches_lanes_linear(self):
        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.normal(size=(5, 48)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(48, 12)) * 0.05, jnp.float32)
        args = (jnp.float32(0.07), jnp.int32(1), jnp.float32(0.02),
                jnp.int32(2))
        wp, cs = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(2),
                                     SPEC)
        wd, cs2 = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(2),
                                      SPEC, weight_store="dense")
        np.testing.assert_array_equal(np.asarray(cs), np.asarray(cs2))
        a = ops.quantized_linear(x, wp, cs, *args, SPEC, backend="xla")
        b = ops.quantized_linear(x, wd, cs2, *args, SPEC, backend="xla",
                                 weight_store="dense")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-5)


class TestServePlans:
    def test_engine_style_layer_plans(self):
        import jax
        from repro.models import common
        from repro.serve import prepare

        from repro.configs import get_config
        cfg = get_config("sparq-cnn")
        key = jax.random.PRNGKey(0)
        p = common.dense_init(key, 32, 16, quantized=True, qcfg=cfg.quant)
        tree = {"blocks": [{"mlp": p}], "head": {"kernel": jnp.zeros((4, 4))}}
        packed = prepare.prepare_serving_params(tree, cfg)
        plans = prepare.build_layer_plans(packed, cfg, batch_rows=4)
        assert list(plans) == ["blocks[0]/mlp"]
        plan = plans["blocks[0]/mlp"]
        assert plan.op == "packed_matmul"
        assert plan.weight_store == "lanes"
        # the memoized planner returns the same object at dispatch shape
        again = plan_lib.plan_packed_matmul(
            4, packed["blocks"][0]["mlp"]["w_packed"].shape[0], 16,
            PackSpec(cfg.quant.w_bits, cfg.quant.a_bits,
                     jnp.dtype(cfg.quant.lane_dtype), cfg.quant.n_pack),
            backend="auto", weight_store="lanes", k_full=None)
        assert again is plan

    def test_dense_layer_plans_use_exact_k(self):
        """With K not a word multiple, the offline dense plan must key the
        exact K (recorded at pack time), matching dispatch-time lookup."""
        import jax
        from repro.configs import get_config
        from repro.models import common
        from repro.serve import prepare

        cfg = get_config("sparq-cnn")
        k = 40                          # per = 16 for w_bits=2; 40 % 16 != 0
        key = jax.random.PRNGKey(1)
        p = common.dense_init(key, k, 16, quantized=True, qcfg=cfg.quant)
        tree = {"mlp": p}
        packed = prepare.prepare_serving_params(tree, cfg, dense_store=True)
        assert packed["mlp"]["k_full"] == k
        plans = prepare.build_layer_plans(packed, cfg, batch_rows=3)
        plan = plans["mlp"]
        assert plan.weight_store == "dense" and plan.k_full == k
        spec = PackSpec(cfg.quant.w_bits, cfg.quant.a_bits,
                        jnp.dtype(cfg.quant.lane_dtype), cfg.quant.n_pack)
        dispatch_plan = plan_lib.plan_packed_matmul(
            3, -(-k // spec.n_pack), 16, spec, backend="auto",
            weight_store="dense", k_full=k)
        assert dispatch_plan is plan
        # and the layer itself stays correct end-to-end
        x = jax.random.normal(key, (3, k))
        y_l = common.dense_apply(
            common.pack_dense_params(p, cfg.quant), x, qcfg=cfg.quant,
            quant_mode="packed", compute_dtype=jnp.float32)
        y_d = common.dense_apply(packed["mlp"], x, qcfg=cfg.quant,
                                 quant_mode="packed",
                                 compute_dtype=jnp.float32)
        np.testing.assert_allclose(np.asarray(y_l), np.asarray(y_d),
                                   rtol=1e-5, atol=1e-5)

"""Sub-byte packed KV cache (DESIGN.md §13): lattice round-trip, ring-wrap,
zero-row scale guard, fused-vs-unfused decode parity, and cache-bytes-aware
engine admission capacity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import packing
from repro.core.quant import QuantConfig
from repro.launch import steps as steps_lib
from repro.models import attention, lm


def kv_cfg(name, kv_bits, **kw):
    cfg = configs.get_config(name, reduced=True)
    return cfg.replace(param_dtype="float32", compute_dtype="float32",
                       quant=QuantConfig(enabled=False, kv_bits=kv_bits),
                       **kw)


# ---------------------------------------------------------------------------
# Lattice round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 2])
def test_kv_quantize_roundtrip_on_lattice(bits):
    """quantize -> pack -> unpack -> dequantize is exact for values already
    on the quantized lattice (idempotence of the storage transform)."""
    rng = np.random.default_rng(bits)
    x = jnp.asarray(rng.normal(size=(2, 5, 3, 20)), jnp.float32)
    stored, scale = attention._kv_quantize(x, bits)
    once = attention._kv_dequantize(stored, scale, jnp.float32, bits, 20)
    stored2, scale2 = attention._kv_quantize(once, bits)
    np.testing.assert_array_equal(np.asarray(stored), np.asarray(stored2))
    twice = attention._kv_dequantize(stored2, scale2, jnp.float32, bits, 20)
    np.testing.assert_allclose(np.asarray(once), np.asarray(twice),
                               rtol=1e-2, atol=1e-3)


@pytest.mark.parametrize("bits", [4, 2])
def test_kv_quantize_error_bound_and_extremes(bits):
    """Max |error| <= scale/2 per element, and +/-amax round-trip exactly
    (the calibrate_absmax qmax-zp convention)."""
    rng = np.random.default_rng(10 + bits)
    x = jnp.asarray(rng.normal(size=(1, 3, 2, 16)), jnp.float32)
    stored, scale = attention._kv_quantize(x, bits)
    dq = attention._kv_dequantize(stored, scale, jnp.float32, bits, 16)
    err = np.abs(np.asarray(dq) - np.asarray(x))
    # scale/2 rounding + bf16 storage of the scale itself (rel ~2^-9 over
    # up to qmax-zp steps)
    bound = np.asarray(scale, np.float32)[..., None] * 0.55 + 1e-5
    assert (err <= bound).all()
    amax = np.abs(np.asarray(x)).max(axis=-1)
    hit = np.abs(np.abs(np.asarray(dq)).max(axis=-1) - amax)
    np.testing.assert_allclose(hit, 0.0, atol=1e-2)


@pytest.mark.parametrize("bits", [4, 2])
@pytest.mark.parametrize("hd", [16, 20, 7])
def test_kv_pack_nondividing_tails(bits, hd):
    """head_dim that does not divide the 32/bits words-per-lane still
    round-trips (zero-padded tail sliced back off)."""
    rng = np.random.default_rng(bits * hd)
    q = jnp.asarray(rng.integers(0, 1 << bits, (2, 3, 2, hd)), jnp.int32)
    words = packing.pack_words(q, bits, axis=-1)
    per = 32 // bits
    assert words.shape[-1] == -(-hd // per)
    back = packing.unpack_words(words, bits, hd, axis=-1)
    np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


def test_kv_zero_rows_no_nan():
    """All-zero k/v rows (untouched cache slots) hit the 1e-8 scale floor:
    no NaN/inf anywhere in store or read-back."""
    for bits in (8, 4, 2):
        z = jnp.zeros((1, 4, 2, 16), jnp.float32)
        stored, scale = attention._kv_quantize(z, bits)
        dq = attention._kv_dequantize(stored, scale, jnp.float32, bits, 16)
        assert np.isfinite(np.asarray(dq)).all()
        np.testing.assert_array_equal(np.asarray(dq), 0.0)


# ---------------------------------------------------------------------------
# Cache layout + ring wrap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,itemsize", [(4, 4), (2, 4)])
def test_init_kv_cache_packed_layout(bits, itemsize):
    cfg = kv_cfg("granite-3-8b", bits)
    c = attention.init_kv_cache(cfg, 2, 32)
    hd = cfg.resolved_head_dim
    per = 32 // bits
    assert c["k"].dtype == jnp.int32
    assert c["k"].shape == (2, 32, cfg.num_kv_heads, -(-hd // per))
    assert c["k_scale"].dtype == jnp.bfloat16


def test_unsupported_kv_bits_rejected_at_config():
    with pytest.raises(ValueError, match="kv_bits"):
        QuantConfig(enabled=False, kv_bits=3)


@pytest.mark.parametrize("bits", [4, 2])
def test_ring_wrap_past_max_len(bits):
    """Scalar-slot writes past the ring size land at slot pos % size with
    exactly the quantized content of the overwriting token."""
    rng = np.random.default_rng(17)
    size, hd = 4, 16
    cache = {
        "k": jnp.zeros((1, size, 2, hd * bits // 32), jnp.int32),
        "v": jnp.zeros((1, size, 2, hd * bits // 32), jnp.int32),
        "k_scale": jnp.zeros((1, size, 2), jnp.bfloat16),
        "v_scale": jnp.zeros((1, size, 2), jnp.bfloat16),
    }
    ks = [jnp.asarray(rng.normal(size=(1, 1, 2, hd)), jnp.float32)
          for _ in range(10)]
    for pos, k in enumerate(ks):
        cache = attention._cache_write(cache, k, k, pos % size, bits)
    for slot in range(size):
        pos = max(p for p in range(10) if p % size == slot)   # latest write
        want, _ = attention._kv_quantize(ks[pos], bits)
        np.testing.assert_array_equal(np.asarray(cache["k"][:, slot]),
                                      np.asarray(want[:, 0]))


@pytest.mark.parametrize("name", ["granite-3-8b"])
def test_packed_kv_sliding_window_decode_consistent(name):
    """Ragged two-slot decode over a sliding-window ring with a 4-bit cache
    matches the same sequences decoded alone (per-row quantization is batch
    invariant; ring wrap exercised past the window)."""
    cfg = kv_cfg(name, 4, sliding_window=6)
    rng = np.random.default_rng(23)
    params = lm.init_params(jax.random.PRNGKey(23), cfg)
    decode = steps_lib.make_decode_step(cfg)
    lens, started = (11, 7), (0, 3)
    toks = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]

    def single(t):
        caches = lm.init_caches(cfg, 1, 16, dtype=jnp.float32)
        logits = None
        for i in range(len(t)):
            logits, caches = decode(params, caches,
                                    {"tokens": jnp.asarray(t[None, i:i + 1])},
                                    jnp.int32(i))
        return np.asarray(logits)[0]

    refs = [single(t) for t in toks]
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].dtype == jnp.int32
    assert caches[0]["attn"]["k"].shape[1] == 6      # ring bounded by window
    pos = np.zeros(2, np.int32)
    last = {}
    for tick in range(max(st + ln for st, ln in zip(started, lens))):
        tokens = np.zeros((2, 1), np.int32)
        valid = np.zeros(2, np.int32)
        for s in range(2):
            tl = tick - started[s]
            if 0 <= tl < lens[s]:
                tokens[s, 0] = toks[s][tl]
                valid[s] = 1
        logits, caches = decode(params, caches, {"tokens": jnp.array(tokens)},
                                jnp.array(pos), jnp.array(valid))
        for s in range(2):
            if valid[s]:
                pos[s] += 1
                if tick - started[s] == lens[s] - 1:
                    last[s] = np.asarray(logits[s])
    for s in range(2):
        np.testing.assert_allclose(last[s], refs[s], rtol=2e-3, atol=2e-3)


# ---------------------------------------------------------------------------
# Ring position bookkeeping (wraparound edge cases)
# ---------------------------------------------------------------------------

def _ring_ref(last, size):
    """Brute force: slot s holds the latest position p <= last with
    p % size == s (-1 if never written)."""
    out = np.full(size, -1, np.int64)
    for p in range(last + 1):
        out[p % size] = p
    return out


@pytest.mark.parametrize("size", [4, 6])
def test_ring_positions_window_equals_size(size):
    """size == window — the ring is exactly the attention window, so every
    slot flips meaning on the wrap step; positions must match the brute
    force 'latest p with p % size == s' definition through two laps."""
    for last in (size - 1, size, 2 * size - 1, 2 * size):
        got = np.asarray(attention._ring_positions(last, size, size))
        np.testing.assert_array_equal(got, _ring_ref(last, size))


def test_ring_positions_last_at_final_slot():
    """last == size - 1: ring exactly full, one step before the first wrap
    — positions equal slot indices — and the very next write (last ==
    size) rewrites only slot 0."""
    size = 8
    got = np.asarray(attention._ring_positions(size - 1, size, size))
    np.testing.assert_array_equal(got, np.arange(size))
    nxt = np.asarray(attention._ring_positions(size, size, size))
    np.testing.assert_array_equal(nxt, [size] + list(range(1, size)))


def test_ring_positions_batch_matches_scalar():
    """The batched variant is row-for-row the scalar one, including rows
    mid-wrap and rows exactly at last == size - 1."""
    size = 6
    lasts = np.array([0, size - 1, size, 2 * size - 1, 3], np.int32)
    batch = np.asarray(attention._ring_positions_batch(
        jnp.asarray(lasts), size, size))
    for i, last in enumerate(lasts):
        np.testing.assert_array_equal(
            batch[i],
            np.asarray(attention._ring_positions(int(last), size, size)))


def test_ring_positions_batch_no_window_empty_rows():
    """window == 0 (full-length cache, no wrap): slots past last read -1,
    and a never-written row (last == -1) is entirely empty."""
    size = 5
    lasts = jnp.asarray([-1, 0, size - 1], jnp.int32)
    got = np.asarray(attention._ring_positions_batch(lasts, size, 0))
    np.testing.assert_array_equal(got[0], -np.ones(size))
    np.testing.assert_array_equal(got[1], [0, -1, -1, -1, -1])
    np.testing.assert_array_equal(got[2], np.arange(size))


# ---------------------------------------------------------------------------
# Fused-dequant read path parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [4, 2])
@pytest.mark.parametrize("sq,chunk", [(1, 512), (12, 4)])
def test_fused_dequant_matches_unfused_reference(bits, sq, chunk):
    """_chunked_attention with the packed cache expanded inside the chunk
    body is BIT-EXACT vs first materializing the dequantized cache and
    attending over it (same lattice, same float ops)."""
    rng = np.random.default_rng(31 * bits + sq)
    b, sk, h, kvh, hd = 2, 10, 4, 2, 20
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    kf = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), jnp.float32)
    vf = jnp.asarray(rng.normal(size=(b, sk, kvh, hd)), jnp.float32)
    cache = {}
    cache["k"], cache["k_scale"] = attention._kv_quantize(kf, bits)
    cache["v"], cache["v_scale"] = attention._kv_quantize(vf, bits)
    qpos = jnp.broadcast_to(
        (sk - sq + jnp.arange(sq))[None, :], (b, sq))

    def mask_fn(qpos):
        return qpos[:, :, None] >= jnp.arange(sk)[None, None, :]

    fused = attention._chunked_attention(
        q, lambda: attention._cache_read(cache, jnp.float32, bits, hd),
        mask_fn, qpos, chunk)
    k_pre, v_pre = attention._cache_read(cache, jnp.float32, bits, hd)
    unfused = attention._chunked_attention(
        q, lambda: (k_pre, v_pre), mask_fn, qpos, chunk)
    np.testing.assert_array_equal(np.asarray(fused), np.asarray(unfused))


@pytest.mark.parametrize("bits,tol", [(8, 0.05), (4, 0.2), (2, 0.6)])
def test_quantized_kv_decode_tracks_full_precision(bits, tol):
    """Model-level: decode through a kv_bits cache stays close to the bf16
    full forward; looser bits, looser tolerance (head_dim=20 also exercises
    the non-dividing word tail in a real model)."""
    cfg = kv_cfg("granite-3-8b", bits, head_dim=20)
    rng = np.random.default_rng(7)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    decode = steps_lib.make_decode_step(cfg)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    logits = None
    for t in range(12):
        logits, caches = decode(params, caches,
                                {"tokens": tokens[:, t:t + 1]}, jnp.int32(t))
    ref = np.asarray(full[:, -1])
    got = np.asarray(logits)
    assert np.isfinite(got).all()
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert corr > 1 - tol, corr


# ---------------------------------------------------------------------------
# Capacity math
# ---------------------------------------------------------------------------

def test_cache_bytes_shrink_and_budget_slots():
    """4-bit cache bytes/slot shrink >= 3.5x vs bf16 at head_dim 64, and a
    fixed HBM budget admits proportionally more engine slots."""
    from repro.serve.config import EngineConfig
    from repro.serve.engine import ServingEngine
    from repro.serve.prepare import cache_bytes_per_slot
    base = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", head_dim=64)
    max_len = 128
    bytes_of = {
        bits: cache_bytes_per_slot(
            base.replace(quant=QuantConfig(enabled=False, kv_bits=bits)),
            max_len)
        for bits in (0, 8, 4, 2)}
    assert bytes_of[0] / bytes_of[8] >= 1.8
    assert bytes_of[0] / bytes_of[4] >= 3.5
    assert bytes_of[0] / bytes_of[2] >= 6.0

    params = lm.init_params(
        jax.random.PRNGKey(0),
        base.replace(quant=QuantConfig(enabled=False, kv_bits=0)))
    budget = 4 * bytes_of[0]
    slots = {}
    for bits in (0, 4):
        cfg = base.replace(quant=QuantConfig(enabled=False, kv_bits=bits))
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_len=max_len, packed=False, hbm_cache_budget=budget))
        slots[bits] = eng.max_batch
        rep = eng.capacity_report()
        assert rep["cache_bytes_per_slot"] == bytes_of[bits]
        assert rep["slots"] == eng.max_batch
    assert slots[0] == 4
    assert slots[4] >= int(3.5 * slots[0])

    with pytest.raises(ValueError, match="hbm_cache_budget"):
        ServingEngine(base, params, config=EngineConfig(
            max_len=max_len, packed=False, hbm_cache_budget=1))


def test_engine_end_to_end_with_packed_kv_cache():
    """The continuous-batching engine generates finite, reproducible output
    through a 2-bit packed cache (write path: ragged scatter; read path:
    fused dequant) and matches its own single-request schedule."""
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Request, ServingEngine
    cfg = kv_cfg("stablelm-1.6b", 2)
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(40)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 3, 5)]

    def run(max_batch):
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=max_batch, max_len=32, packed=False,
            prefill_chunk=4))
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        return {r.uid: tuple(r.output) for r in eng.run_to_completion()}

    assert run(2) == run(1)

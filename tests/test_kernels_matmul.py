"""Pallas ulppack_matmul / int_matmul / quantize_pack vs ref.py oracles.

Kernels run with interpret=True (CPU container; TPU is the lowering target).
Integer paths must match EXACTLY.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stubs

from repro.core.packing import PackSpec
from repro.kernels import ops, ref
from repro.kernels.ulppack_matmul import int_matmul, ulppack_matmul
from repro.core import packing

given, settings, st = hypothesis_or_stubs()


def lattice(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2**bits, size=shape), jnp.int32)


SPECS = [
    PackSpec(1, 1, jnp.int8.dtype),
    PackSpec(2, 1, jnp.int8.dtype),
    PackSpec(1, 1, jnp.int16.dtype),
    PackSpec(2, 2, jnp.int16.dtype),
    PackSpec(3, 2, jnp.int16.dtype),
    PackSpec(3, 3, jnp.int16.dtype),
    PackSpec(4, 3, jnp.int16.dtype),
    PackSpec(1, 1, jnp.int16.dtype, n_pack=4),
]


class TestUlppackMatmulKernel:
    @pytest.mark.parametrize("spec", SPECS, ids=str)
    def test_exact_small(self, spec):
        rng = np.random.default_rng(1)
        m, k, n = 17, 130, 9
        q_a, q_w = lattice(rng, (m, k), spec.a_bits), lattice(rng, (k, n),
                                                              spec.w_bits)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        got = ulppack_matmul(ap, wp, spec, block_m=8, block_n=8, chunks=2,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.matmul_i32_ref(q_a, q_w)))

    @given(st.integers(1, 40), st.integers(1, 200), st.integers(1, 24),
           st.sampled_from([(1, 1), (2, 2), (3, 3)]))
    @settings(max_examples=8, deadline=None)
    def test_shape_sweep(self, m, k, n, wa):
        spec = PackSpec(wa[0], wa[1], jnp.int16.dtype)
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        q_a, q_w = lattice(rng, (m, k), spec.a_bits), lattice(rng, (k, n),
                                                              spec.w_bits)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        got = ulppack_matmul(ap, wp, spec, block_m=16, block_n=16, chunks=3,
                             interpret=True)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.matmul_i32_ref(q_a, q_w)))

    def test_worst_case_lattice_at_tile_bound(self):
        spec = PackSpec(3, 3, jnp.int16.dtype)   # k_tile = 2 (tight)
        k = 64
        q_a = jnp.full((4, k), spec.max_a, jnp.int32)
        q_w = jnp.full((k, 4), spec.max_w, jnp.int32)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        got = ulppack_matmul(ap, wp, spec, block_m=8, block_n=8, chunks=4,
                             interpret=True)
        assert int(got[0, 0]) == k * spec.max_a * spec.max_w


class TestIntMatmulKernel:
    @pytest.mark.parametrize("bits", [8, 16])
    def test_exact(self, bits):
        rng = np.random.default_rng(9)
        q_a = jnp.asarray(rng.integers(-100, 100, (33, 257)), jnp.int32)
        q_w = jnp.asarray(rng.integers(-100, 100, (257, 19)), jnp.int32)
        dt = jnp.int8 if bits == 8 else jnp.int16
        q_a8 = jnp.clip(q_a, -127, 127).astype(dt)
        q_w8 = jnp.clip(q_w, -127, 127).astype(dt)
        got = int_matmul(q_a8, q_w8, block_m=16, block_n=16, block_k=64,
                         interpret=True)
        want = ref.matmul_i32_ref(q_a8.astype(jnp.int32),
                                  q_w8.astype(jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestQuantizePackKernel:
    @pytest.mark.parametrize("spec", [PackSpec(2, 2, jnp.int16.dtype),
                                      PackSpec(1, 1, jnp.int8.dtype)], ids=str)
    def test_matches_ref(self, spec):
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.normal(size=(37, 129)), jnp.float32)
        scale = jnp.float32(0.1)
        zp = jnp.int32(1 << (spec.a_bits - 1))
        from repro.kernels.quant_pack import quantize_pack
        packed, rs = quantize_pack(x, scale, zp, spec, block_m=16,
                                   block_k=32, interpret=True)
        want_p, want_rs = ref.quantize_pack_ref(x, scale, zp, spec)
        np.testing.assert_array_equal(np.asarray(packed), np.asarray(want_p))
        np.testing.assert_array_equal(np.asarray(rs[:, 0]),
                                      np.asarray(want_rs))


class TestQuantizedLinearEndToEnd:
    def test_matches_float_oracle(self):
        spec = PackSpec(3, 3, jnp.int16.dtype)
        rng = np.random.default_rng(11)
        x = jnp.asarray(rng.normal(size=(5, 96)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(96, 7)) * 0.2, jnp.float32)
        a_scale, a_zp = jnp.float32(0.05), jnp.int32(4)
        w_scale, w_zp = jnp.float32(0.01), jnp.int32(4)
        wp, col_sums = ops.prepare_weights(w, w_scale, w_zp, spec)
        got = ops.quantized_linear(x, wp, col_sums, a_scale, a_zp, w_scale,
                                   w_zp, spec, backend="xla")
        want = ref.quantized_linear_ref(x, w, a_scale, a_zp, w_scale, w_zp,
                                        spec.a_bits, spec.w_bits)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-5)

    def test_pallas_and_xla_backends_agree(self):
        spec = PackSpec(2, 2, jnp.int16.dtype)
        rng = np.random.default_rng(13)
        x = jnp.asarray(rng.normal(size=(3, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(64, 9)) * 0.3, jnp.float32)
        wp, cs = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(2), spec)
        a = ops.quantized_linear(x, wp, cs, jnp.float32(0.07), jnp.int32(1),
                                 jnp.float32(0.02), jnp.int32(2), spec,
                                 backend="pallas")
        b = ops.quantized_linear(x, wp, cs, jnp.float32(0.07), jnp.int32(1),
                                 jnp.float32(0.02), jnp.int32(2), spec,
                                 backend="xla")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

"""Speculative decoding (DESIGN.md §19): rejection-sampling exactness at
the unit level, engine-level token-for-token identity at temperature 0,
statistical match at temperature > 0, paged + prefix-sharing composition
(the draft full-prompt-replay stash path), and config validation."""

import dataclasses

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.models import lm
from repro.serve import speculative as spec_lib
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import Request, ServingEngine


def float_cfg(name="stablelm-1.6b", **kw):
    cfg = configs.get_config(name, reduced=True)
    return cfg.replace(param_dtype="float32", compute_dtype="float32",
                       quant=QuantConfig(enabled=False),
                       capacity_factor=8.0, **kw)


# ---------------------------------------------------------------------------
# Unit level: the rejection rule's output distribution
# ---------------------------------------------------------------------------

def test_accept_tokens_greedy_is_argmax_prefix():
    """Greedy accept/reject: committed tokens are the target argmaxes,
    stopping right after the first draft mismatch."""
    vocab = 5
    rows = np.full((4, vocab), -10.0)
    argmaxes = [2, 0, 3, 1]
    for i, a in enumerate(argmaxes):
        rows[i, a] = 1.0
    sp = SamplingParams(temperature=0.0)
    rng = np.random.default_rng(0)
    # drafts match rows 0-1, mismatch at row 2 -> commit argmax there, stop
    out = spec_lib.accept_tokens(rows, np.array([2, 0, 4]), sp, rng)
    assert out == [2, 0, 3]
    # all drafts match -> bonus token from the last row
    out = spec_lib.accept_tokens(rows, np.array([2, 0, 3]), sp, rng)
    assert out == [2, 0, 3, 1]
    # empty draft (limit 0) degenerates to plain sampling from row 0
    out = spec_lib.accept_tokens(rows[:1], np.array([], np.int32), sp, rng)
    assert out == [2]


def _first_token_histogram(row, drafted, sp, trials, seed):
    counts = np.zeros(row.shape[-1])
    rng = np.random.default_rng(seed)
    for _ in range(trials):
        out = spec_lib.accept_tokens(row[None].repeat(2, 0), drafted, sp,
                                     rng)
        counts[out[0]] += 1
    return counts / trials


@pytest.mark.parametrize("draft_tok", [0, 3])
def test_accept_tokens_marginal_matches_target(draft_tok):
    """The committed first token's marginal equals target-only sampling
    p(t) for any draft proposal — the exactness guarantee, checked
    empirically: accept-d-w.p.-p(d) + masked resample must reproduce p
    whether the draft proposed a likely (0) or unlikely (3) token."""
    rng0 = np.random.default_rng(42)
    row = rng0.normal(size=7) * 2.0
    sp = SamplingParams(temperature=0.8, top_k=4)
    p = spec_lib.probs_for(row, sp)
    trials = 20_000
    hist = _first_token_histogram(row, np.array([draft_tok]), sp, trials,
                                  seed=draft_tok)
    assert 0.5 * np.abs(hist - p).sum() < 0.02  # total variation


def test_sample_token_matches_probs_for():
    """sample_token is the one sampling primitive: greedy is argmax, and
    stochastic draws follow probs_for's transform."""
    rng0 = np.random.default_rng(7)
    row = rng0.normal(size=6)
    assert spec_lib.sample_token(row, SamplingParams(), None) \
        == int(np.argmax(row))
    sp = SamplingParams(temperature=0.5, top_k=3)
    p = spec_lib.probs_for(row, sp)
    assert np.all(p[np.argsort(row)[:3]] == 0)   # outside top-k masked
    rng = np.random.default_rng(8)
    draws = np.bincount([spec_lib.sample_token(row, sp, rng)
                         for _ in range(8000)], minlength=6) / 8000
    assert 0.5 * np.abs(draws - p).sum() < 0.03


# ---------------------------------------------------------------------------
# Engine level: identity / statistical match vs target-only decode
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, prompts, *, max_new=6, sampling=None,
                **eng_kw):
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=32, prefill_chunk=4,
        sampling=sampling or SamplingParams(), **eng_kw))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    done = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
    return done, eng


@pytest.fixture(scope="module")
def float_model():
    cfg = float_cfg()
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 3, 11)]
    return cfg, params, prompts


@pytest.mark.parametrize("k", [2, 4])
def test_engine_speculative_greedy_identity(k, float_model):
    """At temperature 0 speculative decode is token-for-token the plain
    engine's output, for k in {2, 4}; every drafted token the (identical)
    draft proposes is accepted."""
    cfg, params, prompts = float_model
    base, _ = _run_engine(cfg, params, prompts, packed=False)
    got, eng = _run_engine(cfg, params, prompts, packed=False,
                           speculative_k=k)
    assert got == base
    rep = eng.metrics.report()
    assert rep["spec_cycles"] > 0
    assert rep["drafted_tokens"] > 0
    # float draft == float target, greedy: drafts always match
    assert rep["acceptance_rate"] == 1.0
    assert rep["accepted_tokens"] <= rep["drafted_tokens"]
    # each (slot, cycle) participation verifies its drafts + 1 bonus row;
    # spec_cycles counts PASSES, so it lower-bounds participations (a
    # pass may carry up to max_batch live slots)
    overhead = rep["verify_tokens"] - rep["drafted_tokens"]
    assert rep["spec_cycles"] <= overhead <= 2 * rep["spec_cycles"]


def test_engine_speculative_sampled_statistical_match(float_model):
    """temperature > 0: rejection sampling must reproduce target-only
    sampling in distribution, not token-for-token (the rng streams
    advance differently).  Checked at matched seeds: every request's
    FIRST token is identical (sampled pre-speculation from the same
    logits with a freshly-seeded per-slot rng), and across many
    same-prompt requests — each uid is an independent rng stream — the
    SECOND token's histogram, conditioned on a shared first token and
    with top_k=2 bounding its support, matches the plain engine's."""
    cfg, params, _ = float_model
    rng = np.random.default_rng(11)
    prompt = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    n_req = 48
    prompts = [prompt] * n_req
    sp = SamplingParams(temperature=1.5, top_k=2, seed=3)
    base, _ = _run_engine(cfg, params, prompts, packed=False, sampling=sp,
                          max_new=3)
    got, eng = _run_engine(cfg, params, prompts, packed=False, sampling=sp,
                           max_new=3, speculative_k=2)
    assert eng.metrics.report()["spec_cycles"] > 0
    for uid in base:
        assert got[uid][0] == base[uid][0]
    # second token, conditioned on the modal first token: same prompt +
    # same t1 = same target conditional, support <= 2 under top_k=2
    t1 = np.array([base[u][0] for u in sorted(base)])
    modal = np.bincount(t1).argmax()
    keep = [u for u in sorted(base) if base[u][0] == modal]
    assert len(keep) >= 12                    # enough conditioned samples
    vals = sorted({base[u][1] for u in keep} | {got[u][1] for u in keep})
    hb = np.array([[base[u][1] for u in keep].count(v) for v in vals],
                  np.float64) / len(keep)
    hg = np.array([[got[u][1] for u in keep].count(v) for v in vals],
                  np.float64) / len(keep)
    assert 0.5 * np.abs(hb - hg).sum() < 0.35, (hb, hg)


def test_engine_speculative_paged_prefix_sharing_identity(float_model):
    """paged + prefix sharing + speculation compose: the target
    prefix-skips a shared prompt while the draft replays it in full (the
    first-token stash path), and outputs still match plain paged decode
    token for token."""
    cfg, params, _ = float_model
    rng = np.random.default_rng(12)
    shared = rng.integers(0, cfg.vocab_size, 9).astype(np.int32)
    prompts = [shared,
               np.concatenate([shared[:6], rng.integers(
                   0, cfg.vocab_size, 4).astype(np.int32)]),
               shared.copy()]
    base, _ = _run_engine(cfg, params, prompts, packed=False, paged=True,
                          page_size=4, max_new=5)
    got, eng = _run_engine(cfg, params, prompts, packed=False, paged=True,
                           page_size=4, max_new=5, speculative_k=3)
    assert got == base
    assert eng.pool.prefix_hits >= 1          # sharing actually engaged
    assert eng.metrics.report()["acceptance_rate"] == 1.0
    # draft pool fully drained back after retirement
    assert eng.spec.pool.report()["free_pages"] == eng.spec.num_pages


def test_engine_packed_draft_identity_and_report():
    """A packed engine re-packs the draft at draft_w_bits; outputs still
    equal target-only greedy decode regardless of draft fidelity, and the
    capacity report carries the draft precision."""
    cfg = configs.get_config("stablelm-1.6b", reduced=True)
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32",
                      capacity_factor=8.0,
                      quant=cfg.quant.replace(w_bits=4, a_bits=4,
                                              lane_dtype="int32",
                                              pack_shift=None))
    params = lm.init_params(jax.random.PRNGKey(6), cfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 3)]
    base, _ = _run_engine(cfg, params, prompts, packed=True)
    got, eng = _run_engine(cfg, params, prompts, packed=True,
                           speculative_k=2, draft_w_bits=2)
    assert got == base
    spec_rep = eng.capacity_report()["speculative"]
    assert spec_rep["draft_packed"] is True
    assert spec_rep["draft_w_bits"] == 2
    assert eng.spec.cfg.quant.w_bits == 2
    assert cfg.quant.w_bits == 4              # target untouched


def test_same_bits_draft_keeps_learned_steps(float_model):
    """When draft bits == target bits the repack keeps the QAT-learned
    step sizes (no recalibration), so the draft IS the target numerically
    and greedy acceptance is exactly 1."""
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", capacity_factor=8.0)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    rng = np.random.default_rng(14)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(2)]
    got, eng = _run_engine(cfg, params, prompts, packed=True,
                           speculative_k=2,
                           draft_w_bits=cfg.quant.w_bits)
    assert eng.metrics.report()["acceptance_rate"] == 1.0


# ---------------------------------------------------------------------------
# Config surface
# ---------------------------------------------------------------------------

def test_engine_config_speculative_validation():
    with pytest.raises(ValueError, match="speculative_k"):
        EngineConfig(speculative_k=-1)
    with pytest.raises(ValueError, match="draft_w_bits"):
        EngineConfig(speculative_k=2, draft_w_bits=8)
    with pytest.raises(ValueError, match="draft_kv_bits"):
        EngineConfig(speculative_k=2, draft_kv_bits=3)
    # draft fields are unchecked while speculation is off
    EngineConfig(speculative_k=0, draft_w_bits=8)


def test_engine_rejects_unsupported_stacks_for_speculation():
    cfg = float_cfg("mixtral-8x7b").replace(sliding_window=6)
    params = lm.init_params(jax.random.PRNGKey(8), cfg)
    with pytest.raises(ValueError, match="sliding-window"):
        ServingEngine(cfg, params, config=EngineConfig(
            packed=False, speculative_k=2))


def test_from_args_speculative_fields():
    ns = dataclasses.make_dataclass("NS", [
        ("max_batch", int, 2), ("max_len", int, 64),
        ("no_packed", bool, True), ("prefill_chunk", int, 16),
        ("max_queue", int, 0), ("temperature", float, 0.0),
        ("top_k", int, 0), ("hbm_cache_budget_mb", float, 0),
        ("autotune", bool, False), ("speculative_k", int, 3),
        ("draft_w_bits", int, 2), ("draft_kv_bits", int, -1)])()
    econf = EngineConfig.from_args(ns)
    assert econf.speculative_k == 3
    assert econf.draft_w_bits == 2
    assert econf.draft_kv_bits is None        # -1 sentinel -> inherit
    ns2 = dataclasses.replace(ns, draft_kv_bits=4)
    assert EngineConfig.from_args(ns2).draft_kv_bits == 4


def test_draft_model_config_precision_drop():
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        quant=configs.get_config("stablelm-1.6b",
                                 reduced=True).quant.replace(
            w_bits=4, a_bits=4, lane_dtype="int32", pack_shift=None,
            kv_bits=4))
    econf = EngineConfig(speculative_k=2, draft_w_bits=2)
    dcfg = spec_lib.draft_model_config(cfg, econf)
    assert dcfg.quant.w_bits == 2 and dcfg.quant.a_bits == 2
    assert dcfg.quant.kv_bits == 4            # inherited
    assert dcfg.quant.lane_dtype == "int16"   # always-feasible layout
    over = EngineConfig(speculative_k=2, draft_w_bits=2, draft_kv_bits=2)
    assert spec_lib.draft_model_config(cfg, over).quant.kv_bits == 2
    # unpacked engine: draft IS the target config
    un = EngineConfig(packed=False, speculative_k=2)
    assert spec_lib.draft_model_config(cfg, un) is cfg

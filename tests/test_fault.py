"""Fault tolerance: checkpoint/restart resume equality, preemption save,
straggler detection — simulated on CPU with a tiny model."""

import jax
import numpy as np

from repro import configs
from repro.core.quant import QuantConfig
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainLoopConfig, Trainer


def tiny_cfg():
    return configs.get_config("stablelm-1.6b", reduced=True).replace(
        num_layers=1, d_model=32, num_heads=2, num_kv_heads=2, d_ff=64,
        vocab_size=128, param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))


def data_cfg(cfg):
    return DataConfig(vocab_size=cfg.vocab_size, seq_len=16, global_batch=4,
                      seed=11)


def test_crash_resume_is_bit_identical(tmp_path):
    """Train 20 steps straight vs 10 steps, 'crash', resume to 20 —
    final params must match exactly (data+optimizer+step all restored)."""
    cfg = tiny_cfg()

    loop_a = TrainLoopConfig(total_steps=20, checkpoint_every=100,
                             checkpoint_dir=str(tmp_path / "a"),
                             log_every=100, async_checkpoint=False)
    t_a = Trainer(cfg, loop_a, data_cfg(cfg), seed=5)
    state_a, _ = t_a.run()

    loop_b = TrainLoopConfig(total_steps=10, checkpoint_every=10,
                             checkpoint_dir=str(tmp_path / "b"),
                             log_every=100, async_checkpoint=False)
    t_b = Trainer(cfg, loop_b, data_cfg(cfg), seed=5)
    t_b.run()  # writes checkpoint at step 10, then "crashes" (process ends)

    loop_b2 = TrainLoopConfig(total_steps=20, checkpoint_every=100,
                              checkpoint_dir=str(tmp_path / "b"),
                              log_every=100, async_checkpoint=False)
    t_b2 = Trainer(cfg, loop_b2, data_cfg(cfg), seed=5)
    state_b, _ = t_b2.run()

    for xa, xb in zip(jax.tree.leaves(state_a["params"]),
                      jax.tree.leaves(state_b["params"])):
        np.testing.assert_array_equal(np.asarray(xa), np.asarray(xb))


def test_preemption_triggers_checkpoint(tmp_path):
    cfg = tiny_cfg()
    loop = TrainLoopConfig(total_steps=50, checkpoint_every=1000,
                           checkpoint_dir=str(tmp_path), log_every=100,
                           async_checkpoint=False)
    t = Trainer(cfg, loop, data_cfg(cfg), seed=1)
    # simulate SIGTERM arriving after construction
    t._preempted = True
    state, stopped_at = t.run()
    assert stopped_at == 1          # stopped at first boundary
    from repro.train import checkpoint
    assert checkpoint.latest_step(tmp_path) == 1


def test_straggler_detection(tmp_path):
    cfg = tiny_cfg()
    events = []
    loop = TrainLoopConfig(total_steps=12, checkpoint_every=1000,
                           checkpoint_dir=str(tmp_path), log_every=100,
                           straggler_factor=2.0, async_checkpoint=False)
    t = Trainer(cfg, loop, data_cfg(cfg), seed=2,
                straggler_cb=events.append)
    # inject a slow step by wrapping the step function
    orig = t.step_fn
    calls = {"n": 0}

    def slow_step(state, batch):
        calls["n"] += 1
        if calls["n"] == 10:
            import time
            time.sleep(1.0)
        return orig(state, batch)

    t.step_fn = slow_step
    t.run()
    assert any(e["step"] == 9 for e in events), events


def test_metrics_drop_during_training(tmp_path):
    """Loss on the motif-structured stream should drop measurably."""
    cfg = tiny_cfg()
    loop = TrainLoopConfig(total_steps=60, checkpoint_every=1000,
                           checkpoint_dir=str(tmp_path), log_every=5,
                           async_checkpoint=False)
    t = Trainer(cfg, loop, data_cfg(cfg), seed=3,
                train_step_kwargs={"peak_lr": 3e-3, "warmup_steps": 10,
                                   "total_steps": 60})
    t.run()
    first = t.metrics_log[0]["loss"]
    last = t.metrics_log[-1]["loss"]
    assert last < first - 0.1, (first, last)

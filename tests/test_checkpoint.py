"""Checkpoint save/restore: round-trip equality, crash consistency, elastic
resharding, garbage collection."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint


def make_state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 4)),
                       "layers": [{"a": jnp.ones((3,))},
                                  {"a": jnp.zeros((3,))}]},
            "step": jnp.int32(17)}


class TestRoundTrip:
    def test_save_restore_equal(self, tmp_path):
        st = make_state()
        checkpoint.save(tmp_path, st, step=17)
        template = jax.eval_shape(lambda: make_state())
        restored, manifest = checkpoint.restore(tmp_path, template)
        assert manifest["step"] == 17
        for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(restored)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_async_save(self, tmp_path):
        st = make_state()
        join = checkpoint.save(tmp_path, st, step=1, async_=True)
        join()
        assert checkpoint.latest_step(tmp_path) == 1

    def test_uncommitted_checkpoint_ignored(self, tmp_path):
        st = make_state()
        checkpoint.save(tmp_path, st, step=5)
        # simulate a crash mid-save: step_9 exists but no COMMITTED marker
        d = tmp_path / "step_9"
        d.mkdir()
        (d / "manifest.json").write_text("{}")
        assert checkpoint.latest_step(tmp_path) == 5

    def test_structure_mismatch_raises(self, tmp_path):
        checkpoint.save(tmp_path, make_state(), step=2)
        bad_template = {"params": {"w": jax.ShapeDtypeStruct((8, 4),
                                                             jnp.float32)}}
        with pytest.raises(ValueError):
            checkpoint.restore(tmp_path, bad_template)

    def test_garbage_collect_keeps_latest(self, tmp_path):
        for s in (1, 2, 3, 4, 5):
            checkpoint.save(tmp_path, make_state(), step=s)
        checkpoint.garbage_collect(tmp_path, keep=2)
        assert checkpoint.latest_step(tmp_path) == 5
        assert not (tmp_path / "step_1").exists()
        assert (tmp_path / "step_4").exists()


class TestElasticReshard:
    def test_restore_to_different_mesh(self, tmp_path):
        """Save from a 1-device layout, restore sharded onto a 2x1 mesh (or
        whatever the host offers) — elastic restart path."""
        st = {"w": jnp.arange(16.0).reshape(8, 2)}
        checkpoint.save(tmp_path, st, step=1)
        mesh = jax.make_mesh((1, 1), ("data", "model"))
        sh = {"w": jax.sharding.NamedSharding(
            mesh, jax.sharding.PartitionSpec("data", None))}
        template = jax.eval_shape(lambda: st)
        restored, _ = checkpoint.restore(tmp_path, template, shardings=sh)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(st["w"]))
        assert restored["w"].sharding.spec == \
            jax.sharding.PartitionSpec("data", None)

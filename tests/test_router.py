"""Replica-fleet Router (serve/router.py, DESIGN.md §17).

The tentpole invariant: a fleet of N engine replicas behind the Router is
token-for-token identical to one engine — per-slot sampling is keyed on
``(sampling.seed, uid)``, engine-independent, so WHERE a request lands
never changes WHAT it generates.  On top of that identity the Router adds
least-loaded placement, per-replica backpressure feeding the fleet
spillover queue, session affinity, and drain/restore with param handoff
through the train/checkpoint machinery.

The ``(data=2, model=2)`` mesh tests ride the `shard` CI lane (forced
8-device CPU host) and skip below 8 devices; everything else runs on the
plain tier-1 lane with process-local replicas.
"""

import jax
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch.mesh import make_serving_mesh, replica_meshes
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import Request, ServingEngine
from repro.serve.router import Router, aggregate_reports
from repro.train import checkpoint


def float_cfg(name="stablelm-1.6b"):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))


def packed_cfg(name="stablelm-1.6b", w_bits=2, kv_bits=4):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=w_bits, a_bits=w_bits,
                          lane_dtype="int16", kv_bits=kv_bits))


@pytest.fixture(scope="module")
def tiny():
    cfg = float_cfg()
    return cfg, lm_params(cfg)


def lm_params(cfg):
    from repro.models import lm
    return lm.init_params(jax.random.PRNGKey(0), cfg)


def seeded_prompts(cfg, lens, seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


def fleet_config(**kw):
    kw.setdefault("max_batch", 2)
    kw.setdefault("max_len", 32)
    kw.setdefault("packed", False)
    kw.setdefault("prefill_chunk", 4)
    return EngineConfig(**kw)


# ---------------------------------------------------------------------------
# Placement
# ---------------------------------------------------------------------------

def test_least_loaded_placement_spreads(tiny):
    cfg, params = tiny
    router = Router(cfg, params, config=fleet_config(), replicas=2)
    handles = [router.submit(p, max_new_tokens=3)
               for p in seeded_prompts(cfg, (5, 5, 5, 5, 5, 5))]
    placed = [h.replica for h in handles]
    assert placed == [0, 1, 0, 1, 0, 1]     # ties break to lowest index
    done = router.run_to_completion()
    assert len(done) == 6 and all(h.done for h in done)


def test_fleet_token_identical_to_single_engine(tiny):
    """Outputs must not depend on which replica served the request."""
    cfg, params = tiny
    prompts = seeded_prompts(cfg, (7, 3, 11, 5))
    sampling = [None, SamplingParams(temperature=0.8, top_k=5, seed=3),
                None, SamplingParams(temperature=1.0, seed=9)]

    single = ServingEngine(cfg, params, config=fleet_config())
    for i, (p, sp) in enumerate(zip(prompts, sampling)):
        assert single.submit(Request(uid=i, prompt=p, max_new_tokens=5,
                                     sampling=sp))
    want = {r.uid: tuple(r.output) for r in single.run_to_completion()}

    router = Router(cfg, params, config=fleet_config(), replicas=2)
    handles = [router.submit(p, sp, max_new_tokens=5)
               for p, sp in zip(prompts, sampling)]
    router.run_to_completion()
    assert len({h.replica for h in handles}) == 2   # really load-balanced
    got = {h.uid: tuple(h.output) for h in handles}
    assert got == want


# ---------------------------------------------------------------------------
# Backpressure -> spillover
# ---------------------------------------------------------------------------

def test_spillover_under_full_replicas(tiny):
    cfg, params = tiny
    router = Router(cfg, params, replicas=2,
                    config=fleet_config(max_batch=1, max_queue=1))
    handles = [router.submit(p, max_new_tokens=3)
               for p in seeded_prompts(cfg, (4,) * 6)]
    # one queued request per replica before any steps; the rest spill
    assert [h.replica for h in handles[:2]] == [0, 1]
    assert all(h.replica is None and h.spilled for h in handles[2:])
    assert router.spilled == 4 and router.num_pending == 6

    done = router.run_to_completion()
    assert len(done) == 6 and all(h.done for h in handles)
    fleet = router.metrics_report()["fleet"]
    # spillover is router-side waiting, never a client-visible rejection
    assert fleet["rejected"] == 0
    assert fleet["retired"] == 6
    assert fleet["spill_pending"] == 0 and fleet["spill_peak"] == 4


def test_spilled_requests_keep_fleet_admission_ttft(tiny):
    """TTFT clocks from Router.submit; spillover wait is client-visible
    latency, so a spilled request's TTFT must cover it."""
    cfg, params = tiny
    router = Router(cfg, params, replicas=1,
                    config=fleet_config(max_batch=1, max_queue=1))
    for p in seeded_prompts(cfg, (4, 4, 4)):
        router.submit(p, max_new_tokens=4)
    router.run_to_completion()
    fleet = router.metrics_report()["fleet"]
    ttft = fleet["ttft_s"]
    # 3 sequential requests through 1 slot: the last one's TTFT includes
    # two full residencies, so the spread must be visibly nonzero
    assert ttft["p95"] > ttft["p50"] > 0


# ---------------------------------------------------------------------------
# Session affinity
# ---------------------------------------------------------------------------

def test_session_affinity_overrides_least_loaded(tiny):
    cfg, params = tiny
    router = Router(cfg, params, config=fleet_config(), replicas=2)
    prompts = seeded_prompts(cfg, (5,) * 5)
    first = router.submit(prompts[0], session="alice", max_new_tokens=3)
    assert first.replica == 0
    # load replica 0 past replica 1 so least-loaded would now pick 1 ...
    router.submit(prompts[1], max_new_tokens=3)     # -> 1 (least loaded)
    router.submit(prompts[2], max_new_tokens=3)     # -> 0 or 1
    pinned = router.submit(prompts[3], session="alice", max_new_tokens=3)
    assert pinned.replica == 0                      # ... but the pin wins
    router.run_to_completion()
    assert router.metrics_report()["fleet"]["sessions"] == 1


def test_full_pinned_replica_waits_not_relocates(tiny):
    """A session whose replica is full WAITS in spillover for that
    replica; landing elsewhere would abandon its cache locality."""
    cfg, params = tiny
    router = Router(cfg, params, replicas=2,
                    config=fleet_config(max_batch=1, max_queue=2))
    router.submit(seeded_prompts(cfg, (4,))[0], session="bob",
                  max_new_tokens=3)
    router.submit(seeded_prompts(cfg, (4,), seed=2)[0], session="bob",
                  max_new_tokens=3)   # fills replica 0's queue of 2
    third = router.submit(seeded_prompts(cfg, (4,), seed=3)[0],
                          session="bob", max_new_tokens=3)
    assert third.spilled and third.replica is None  # replica 1 has room
    router.run_to_completion()
    assert third.replica == 0                       # placed on its pin


# ---------------------------------------------------------------------------
# Drain / restore
# ---------------------------------------------------------------------------

def test_drain_requeues_waiting_requests(tiny):
    cfg, params = tiny
    router = Router(cfg, params, replicas=2,
                    config=fleet_config(max_batch=1, max_queue=4))
    handles = [router.submit(p, max_new_tokens=3)
               for p in seeded_prompts(cfg, (4,) * 4)]
    assert [h.replica for h in handles] == [0, 1, 0, 1]
    router.step()       # each replica admits its first request to a slot
    info = router.drain(0)
    assert info["requeued"] == 1        # the queued one; the live one ran
    assert handles[2].spilled
    done = router.run_to_completion()
    assert len(done) == 4
    assert handles[2].replica == 1      # re-placed on the survivor
    fleet = router.metrics_report()["fleet"]
    assert fleet["attached"] == 1 and fleet["drains"] == 1
    assert fleet["retired"] == 4        # drained replica's history counts


def test_drain_restore_token_identity(tiny, tmp_path):
    """Drain -> checkpoint handoff -> restore must be invisible in the
    tokens: the restored replica serves exactly what a never-drained
    engine would (packing is deterministic, restore() round-trips the
    params through train/checkpoint)."""
    cfg, params = tiny
    prompts = seeded_prompts(cfg, (7, 3, 5))
    single = ServingEngine(cfg, params, config=fleet_config())
    for i, p in enumerate(prompts):
        single.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    want = [tuple(r.output)
            for r in sorted(single.run_to_completion(), key=lambda r: r.uid)]

    router = Router(cfg, params, config=fleet_config(), replicas=2,
                    checkpoint_dir=tmp_path)
    router.submit(prompts[0], max_new_tokens=4)
    router.run_to_completion()
    info = router.drain(0)
    assert info["checkpoint"] == {"directory": str(tmp_path), "step": 0}
    assert checkpoint.latest_step(tmp_path) == 0
    with pytest.raises(ValueError, match="detached"):
        router.drain(0)

    router.restore(0)
    with pytest.raises(ValueError, match="attached"):
        router.restore(0)
    handles = [router.submit(p, max_new_tokens=4) for p in prompts]
    router.run_to_completion()
    assert [tuple(h.output) for h in handles] == want
    fleet = router.metrics_report()["fleet"]
    assert fleet["drains"] == 1 and fleet["restores"] == 1
    assert fleet["attached"] == 2


def test_run_to_completion_refuses_headless_spillover(tiny):
    cfg, params = tiny
    router = Router(cfg, params, replicas=1,
                    config=fleet_config(max_batch=1, max_queue=1))
    for p in seeded_prompts(cfg, (4,) * 3):
        router.submit(p, max_new_tokens=3)
    router.drain(0)
    assert router.num_pending == 3      # 2 spilled + 1 requeued by drain
    with pytest.raises(RuntimeError, match="restore"):
        router.run_to_completion()
    router.restore(0)
    assert len(router.run_to_completion()) == 3


# ---------------------------------------------------------------------------
# Admission validation + construction
# ---------------------------------------------------------------------------

def test_oversize_request_rejected_at_the_door(tiny):
    cfg, params = tiny
    router = Router(cfg, params, config=fleet_config(max_len=16))
    with pytest.raises(ValueError, match="max_len"):
        router.submit(np.zeros(10, np.int32), max_new_tokens=10)


def test_replica_count_validated(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="replicas"):
        Router(cfg, params, config=fleet_config(), replicas=0)


def test_mesh_contradicting_replicas_rejected(tiny):
    cfg, params = tiny
    mesh = make_serving_mesh(model=1, data=1)
    with pytest.raises(ValueError, match="data"):
        Router(cfg, params, config=fleet_config(), mesh=mesh, replicas=3)


def test_make_serving_mesh_validates_axes():
    with pytest.raises(ValueError, match="data"):
        make_serving_mesh(model=1, data=0)
    with pytest.raises(ValueError, match="model"):
        make_serving_mesh(model=0, data=1)
    mesh = make_serving_mesh(model=1, data=1)
    assert tuple(mesh.axis_names) == ("data", "model")


def test_replica_meshes_requires_serving_axes():
    with pytest.raises(ValueError, match="data.*model"):
        replica_meshes(jax.make_mesh((1,), ("model",)))


def test_aggregate_sums_rates_and_merges_samples():
    """Fleet tok/s is the sum of per-replica rates (disjoint hardware);
    percentiles come from the union of samples, not from per-replica
    percentiles."""
    from repro.serve.engine import Metrics
    a, b = Metrics(), Metrics()
    a.decode_tokens, a.decode_time_s = 100, 2.0     # 50 tok/s
    b.decode_tokens, b.decode_time_s = 300, 2.0     # 150 tok/s
    a.ttft_s, b.ttft_s = [0.1, 0.2], [0.3, 0.4]
    rep = aggregate_reports([a, b])
    assert rep["decode_tok_s"] == 200.0
    assert rep["decode_tokens"] == 400
    assert rep["ttft_s"]["mean"] == pytest.approx(0.25)
    assert rep["ttft_s"]["p50"] == pytest.approx(0.25)


# ---------------------------------------------------------------------------
# (data, model) mesh fleet — the `shard` CI lane (forced 8-device host)
# ---------------------------------------------------------------------------

needs_fleet_mesh = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices for a (data=2, model=2) fleet "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.mark.shard
@needs_fleet_mesh
def test_replica_meshes_carve_disjoint_device_groups():
    mesh = make_serving_mesh(model=2, data=2)
    groups = replica_meshes(mesh)
    assert len(groups) == 2
    ids = [sorted(d.id for d in g.devices.flat) for g in groups]
    assert all(len(i) == 2 for i in ids)
    assert not set(ids[0]) & set(ids[1])    # replicas own disjoint devices
    assert all(tuple(g.axis_names) == ("data", "model") for g in groups)


@pytest.mark.shard
@needs_fleet_mesh
def test_fleet_2x2_token_identical_to_tp2_single():
    """The acceptance bar: a (data=2, model=2) Router — two 2-way-TP
    packed replicas on disjoint device groups — serves token-for-token
    identically to one (model=2) engine, greedy and seeded sampling
    alike, with the merged fleet metrics populated."""
    cfg = packed_cfg()
    params = lm_params(cfg)
    prompts = seeded_prompts(cfg, (7, 3, 11, 5, 6))
    sampling = [None, SamplingParams(temperature=0.9, top_k=8, seed=5),
                None, SamplingParams(temperature=0.7, seed=11), None]

    econf = fleet_config(packed=True)
    single = ServingEngine(cfg, params, config=econf,
                           mesh=make_serving_mesh(2))
    for i, (p, sp) in enumerate(zip(prompts, sampling)):
        assert single.submit(Request(uid=i, prompt=p, max_new_tokens=5,
                                     sampling=sp))
    want = {r.uid: tuple(r.output) for r in single.run_to_completion()}

    router = Router(cfg, params, config=econf,
                    mesh=make_serving_mesh(model=2, data=2))
    handles = [router.submit(p, sp, max_new_tokens=5,
                             session="sess" if i == 2 else None)
               for i, (p, sp) in enumerate(zip(prompts, sampling))]
    router.run_to_completion()
    assert len({h.replica for h in handles}) == 2
    assert {h.uid: tuple(h.output) for h in handles} == want

    rep = router.metrics_report()
    fleet = rep["fleet"]
    assert fleet["replicas"] == fleet["attached"] == 2
    assert fleet["retired"] == 5 and fleet["rejected"] == 0
    assert fleet["decode_tok_s"] > 0 and fleet["ttft_s"]["p95"] > 0
    assert len(rep["replica_reports"]) == 2
    assert router.capacity_report()["fleet_slots"] == 4

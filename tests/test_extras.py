"""Extras: bit-dense weight storage, overlapped collective matmul,
P4 packing edge cases, vmacsr-vs-tile-bound equivalence."""

import subprocess
import sys
import textwrap

import jax.numpy as jnp
import numpy as np
from conftest import hypothesis_or_stubs

from repro.kernels import ops

given, settings, st = hypothesis_or_stubs()


class TestDenseStorage:
    @given(st.integers(1, 4), st.integers(1, 100), st.integers(1, 16))
    @settings(max_examples=20, deadline=None)
    def test_roundtrip(self, w_bits, k, n):
        rng = np.random.default_rng(k * 17 + n)
        q = jnp.asarray(rng.integers(0, 2 ** w_bits, (k, n)), jnp.int32)
        words = ops.dense_store_weights(q, w_bits)
        back = ops.dense_load_weights(words, w_bits, k)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))

    def test_footprint(self):
        q = jnp.zeros((256, 64), jnp.int32)
        words = ops.dense_store_weights(q, 2)
        assert words.size * 4 == 256 * 64 * 2 // 8  # 2 bits/value exactly


class TestCollectiveMatmul:
    def test_all_gather_matmul_subprocess(self):
        script = textwrap.dedent("""
            import os
            os.environ["XLA_FLAGS"] = \
                "--xla_force_host_platform_device_count=4"
            import jax, jax.numpy as jnp, numpy as np
            from repro.parallel.collectives import all_gather_matmul
            mesh = jax.make_mesh((4,), ("model",))
            rng = np.random.default_rng(0)
            x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
            w = jnp.asarray(rng.normal(size=(16, 12)), jnp.float32)
            y = all_gather_matmul(x, w, mesh, axis="model")
            np.testing.assert_allclose(np.asarray(y), np.asarray(x @ w),
                                       rtol=1e-4, atol=1e-4)
            print("CM_OK")
        """)
        from test_pipeline import subprocess_env
        r = subprocess.run([sys.executable, "-c", script],
                           capture_output=True, text=True, timeout=300,
                           env=subprocess_env())
        assert "CM_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-1500:])

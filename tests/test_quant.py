"""Quantizer properties: idempotence, STE gradients, LSQ, PACT, vmacsr ISA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stubs

from repro.core import quant, vmacsr

given, settings, st = hypothesis_or_stubs()


class TestAffine:
    @given(st.integers(2, 8))
    @settings(max_examples=10, deadline=None)
    def test_quantize_idempotent(self, bits):
        rng = np.random.default_rng(bits)
        x = jnp.asarray(rng.normal(size=(64,)), jnp.float32)
        scale, zp = quant.calibrate_minmax(x, bits)
        q = quant.quantize_affine(x, scale, zp, bits)
        dq = quant.dequantize_affine(q, scale, zp)
        q2 = quant.quantize_affine(dq, scale, zp, bits)
        np.testing.assert_array_equal(np.asarray(q), np.asarray(q2))

    def test_lattice_bounds(self):
        x = jnp.linspace(-10, 10, 101)
        scale, zp = quant.calibrate_minmax(x, 3)
        q = quant.quantize_affine(x, scale, zp, 3)
        assert int(q.min()) >= 0 and int(q.max()) <= 7

    def test_minmax_error_bound(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(size=(256,)), jnp.float32)
        scale, zp = quant.calibrate_minmax(x, 8)
        dq = quant.dequantize_affine(
            quant.quantize_affine(x, scale, zp, 8), scale, zp)
        assert float(jnp.max(jnp.abs(dq - x))) <= float(scale) / 2 + 1e-6

    def test_sawb_positive(self):
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.normal(size=(128,)), jnp.float32)
        for bits in (2, 3, 4, 8):
            assert float(quant.sawb_scale(w, bits)) > 0

    @pytest.mark.parametrize("bits", [2, 3, 4, 8])
    def test_absmax_symmetric_extremes_roundtrip(self, bits):
        """+amax must land exactly on qmax (regression: the old scale
        targeted zp steps, sending +amax to 2^bits, which the clip in
        quantize_affine flattened by a full step) and -amax on 2*zp - qmax;
        both dequantize back to +/-amax exactly."""
        amax = 1.7
        x = jnp.asarray([-amax, -amax / 3, 0.0, amax / 2, amax], jnp.float32)
        scale, zp = quant.calibrate_absmax(x, bits, symmetric=True)
        qmax = (1 << bits) - 1
        q = quant.quantize_affine(x, scale, zp, bits)
        assert int(q[-1]) == qmax
        assert int(q[0]) == 2 * zp - qmax
        dq = np.asarray(quant.dequantize_affine(q, scale, zp))
        np.testing.assert_allclose(dq[-1], amax, rtol=1e-6)
        np.testing.assert_allclose(dq[0], -amax, rtol=1e-6)
        # interior points stay within half a step
        assert np.abs(dq - np.asarray(x)).max() <= float(scale) / 2 + 1e-6

    def test_absmax_symmetric_bits1_stays_finite(self):
        """bits=1 has qmax == zp; the qmax-zp denominator must clamp to 1
        (degenerate {-amax, 0} lattice) instead of producing scale=inf."""
        x = jnp.asarray([-2.0, 0.5, 2.0], jnp.float32)
        scale, zp = quant.calibrate_absmax(x, 1, symmetric=True)
        assert np.isfinite(float(scale)) and float(scale) == 2.0 and zp == 1
        q = quant.quantize_affine(x, scale, zp, 1)
        assert int(q.min()) >= 0 and int(q.max()) <= 1


class TestSTE:
    def test_fake_quant_grad_is_masked_identity(self):
        x = jnp.asarray([-5.0, -0.01, 0.0, 0.3, 0.7, 5.0])
        scale, zp = jnp.float32(0.1), jnp.float32(4.0)
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, scale, zp, 3)))(x)
        # range = [(0-4)*0.1, (7-4)*0.1] = [-0.4, 0.3]
        np.testing.assert_array_equal(np.asarray(g),
                                      np.asarray([0., 1., 1., 1., 0., 0.]))

    def test_lsq_step_gradient_sign(self):
        """Values clipped above push the step UP (to widen the range)."""
        x = jnp.full((16,), 10.0)
        step = jnp.float32(0.1)
        dstep = jax.grad(
            lambda s: jnp.sum(quant.lsq_fake_quant(x, s, 4, False)), 0)(step)
        assert float(dstep) > 0

    def test_lsq_forward_matches_fake_quant_midpoint(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(32,)), jnp.float32)
        step = jnp.float32(0.2)
        y = quant.lsq_fake_quant(x, step, 4, True)
        want = quant.fake_quant(x, step, jnp.float32(8.0), 4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want), rtol=1e-6)

    def test_pact_clip_grads(self):
        x = jnp.asarray([-1.0, 0.5, 2.0])
        alpha = jnp.float32(1.0)
        gx = jax.grad(lambda v: jnp.sum(quant.pact_clip(v, alpha, 4)))(x)
        ga = jax.grad(lambda a: jnp.sum(quant.pact_clip(x, a, 4)))(alpha)
        np.testing.assert_array_equal(np.asarray(gx), [0., 1., 0.])
        assert float(ga) == 1.0


class TestVmacsrISA:
    def test_vmacsr_semantics(self):
        vd = jnp.zeros((4,), jnp.int16)
        vs1 = jnp.asarray([17, 34, 51, 100], jnp.int16)   # packed lanes
        vs2 = jnp.asarray([16, 16, 16, 16], jnp.int16)
        out = vmacsr.vmacsr(vd, vs1, vs2, 4)
        np.testing.assert_array_equal(np.asarray(out), [17, 34, 51, 100])

    def test_vmacsr_kills_low_crossterm(self):
        """Per-product shift removes L before accumulation (paper Fig. 2)."""
        spec_shift = 8
        a_packed = jnp.asarray([3 + (2 << 8)], jnp.int32)    # a0=3, a1=2
        w_packed = jnp.asarray([1 + (2 << 8)], jnp.int32)    # w1=1, w0=2
        vd = jnp.zeros((1,), jnp.int32)
        for _ in range(100):   # way beyond the native k_tile for W2A2
            vd = vmacsr.vmacsr(vd, a_packed, w_packed, spec_shift)
        d = int(vd[0]) & 0xFF
        assert d == (100 * (3 * 2 + 2 * 1)) % 256

    def test_instruction_count_model(self):
        native = vmacsr.native_ulppack_instruction_count(256, k_tile=2)
        fused = vmacsr.vmacsr_instruction_count(256, k_tile=2)
        base = vmacsr.int16_instruction_count(256)
        assert fused.total < native.total < base.total * 2
        assert fused.shifts == 0 and native.shifts > 0

"""benchmarks/compare.py — the CI perf-regression gate — and the shared
record schema / timing helpers in benchmarks/common.py."""

import copy
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks import common, compare  # noqa: E402

BASE = {
    "fig4": {"schema": 1, "rows": [
        {"impl": "int16-conv2d", "wall_us": 100.0, "measured_speedup": 1.0},
        {"impl": "ULP-vmacsr(W2A2)", "wall_us": 40.0,
         "measured_speedup": 2.5},
        {"case": "tuned-vs-heuristic/lanes", "heuristic_us": 64.0,
         "tuned_us": 40.0, "tuned_speedup": 1.6},
    ]},
    "serve": {"schema": 1, "rows": {
        "engine": [{"engine": "chunked-prefill-16", "prefill_tok_s": 900.0,
                    "speedup_vs_baseline": 10.0}],
        "kv_cache": [{"kv_bits": 4, "slots_vs_bf16": 4.0,
                      "shrink_vs_bf16": 3.76,
                      "cache_bytes_per_slot": 1024}],
    }},
}


def _cur(mutate=None):
    cur = copy.deepcopy(BASE)
    if mutate:
        mutate(cur)
    return cur


class TestCompare:
    def test_identical_payloads_pass(self):
        findings = compare.compare(BASE, _cur())
        assert compare.gate_failures(findings) == []
        assert all(f["status"] == "ok" for f in findings)

    def test_halved_speedup_fails_gate(self):
        def mutate(c):
            c["fig4"]["rows"][2]["tuned_speedup"] = 0.8  # 2x slowdown

        failures = compare.gate_failures(compare.compare(BASE, _cur(mutate)))
        assert [f["metric"] for f in failures] == ["tuned_speedup"]
        assert failures[0]["status"] == "regressed"

    def test_regression_within_tolerance_passes(self):
        def mutate(c):
            c["fig4"]["rows"][2]["tuned_speedup"] = 1.4  # -12.5% < 25%

        findings = compare.compare(BASE, _cur(mutate), tolerance=0.25)
        assert compare.gate_failures(findings) == []

    def test_floor_violation_fails_even_within_tolerance(self):
        """A row-level floor is a hard same-run bound on the CURRENT run:
        it fails the gate even when the delta vs baseline is tiny, and
        even when the baseline itself sits below the floor (a refreshed
        baseline cannot launder a broken floor)."""
        def floored(value):
            def mutate(c):
                c["serve"]["rows"]["engine"].append(
                    {"case": "speculative/draft-verify",
                     "speculative_speedup": value,
                     "floor": {"speculative_speedup": 1.5}})
            return mutate

        # passing: current >= floor, regardless of baseline state
        findings = compare.compare(_cur(floored(1.4)), _cur(floored(2.0)))
        floors = [f for f in findings if f["metric"].endswith("(floor)")]
        assert [f["status"] for f in floors] == ["ok"]
        # failing: current < floor, baseline identical (delta 0%)
        findings = compare.compare(_cur(floored(1.2)), _cur(floored(1.2)))
        fails = compare.gate_failures(findings)
        assert [(f["metric"], f["status"]) for f in fails] == \
            [("speculative_speedup (floor)", "below-floor")]
        assert fails[0]["base"] == 1.5 and fails[0]["cur"] == 1.2

    def test_floor_metric_missing_from_row_fails(self):
        def mutate(c):
            c["serve"]["rows"]["engine"].append(
                {"case": "speculative/draft-verify",
                 "floor": {"speculative_speedup": 1.5}})

        fails = compare.gate_failures(compare.compare(BASE, _cur(mutate)))
        assert [f["status"] for f in fails] == ["below-floor"]
        assert fails[0]["cur"] is None

    def test_near_unity_speedup_is_report_only(self):
        """A baseline speedup inside NEAR_UNITY_BAND recorded no material
        win; its collapse reports but cannot fail CI on runner noise."""
        base = {"fig4": {"schema": 1, "rows": [
            {"case": "tuned-vs-heuristic/dense", "tuned_speedup": 1.08}]}}
        cur = copy.deepcopy(base)
        cur["fig4"]["rows"][0]["tuned_speedup"] = 0.7
        findings = compare.compare(base, cur)
        assert compare.gate_failures(findings) == []
        assert findings[0]["status"] == "regressed"  # still reported

    def test_improvement_never_fails(self):
        def mutate(c):
            c["serve"]["rows"]["kv_cache"][0]["slots_vs_bf16"] = 8.0

        findings = compare.compare(BASE, _cur(mutate))
        assert compare.gate_failures(findings) == []
        assert any(f["status"] == "improved" for f in findings)

    def test_missing_gated_metric_fails(self):
        def mutate(c):
            del c["serve"]["rows"]["engine"][0]["speedup_vs_baseline"]

        failures = compare.gate_failures(compare.compare(BASE, _cur(mutate)))
        assert [(f["metric"], f["status"]) for f in failures] == \
            [("speedup_vs_baseline", "missing")]

    def test_missing_case_fails_its_gated_metrics(self):
        def mutate(c):
            c["fig4"]["rows"] = c["fig4"]["rows"][:2]

        failures = compare.gate_failures(compare.compare(BASE, _cur(mutate)))
        assert {f["metric"] for f in failures} == {"tuned_speedup"}

    def test_absolute_metrics_report_only_by_default(self):
        def mutate(c):
            c["fig4"]["rows"][0]["wall_us"] = 1000.0      # 10x slower
            c["serve"]["rows"]["engine"][0]["prefill_tok_s"] = 1.0

        findings = compare.compare(BASE, _cur(mutate))
        assert compare.gate_failures(findings) == []
        regressed = {f["metric"] for f in findings
                     if f["status"] == "regressed"}
        assert {"wall_us", "prefill_tok_s"} <= regressed  # still reported

    def test_gate_absolute_arms_wall_and_throughput(self):
        def mutate(c):
            c["fig4"]["rows"][0]["wall_us"] = 200.0       # injected 2x

        findings = compare.compare(BASE, _cur(mutate), gate_absolute=True)
        assert {f["metric"] for f in compare.gate_failures(findings)} == \
            {"wall_us"}

    def test_extra_gate_regex(self):
        def mutate(c):
            c["serve"]["rows"]["kv_cache"][0]["cache_bytes_per_slot"] = 9999

        findings = compare.compare(BASE, _cur(mutate),
                                   extra_gates=(r"cache_bytes_per_slot",))
        assert compare.gate_failures(findings)

    def test_schema_mismatch_rejected(self):
        bad = _cur(lambda c: c["fig4"].__setitem__("schema", 99))
        with pytest.raises(ValueError, match="schema"):
            compare.compare(bad, _cur())

    def test_non_numeric_values_skipped(self):
        base = {"fig5": {"schema": 1, "rows": [
            {"mode": "native", "w_bits": 4, "a_bits": 4,
             "speedup_vs_int16": "overflow"}]}}
        findings = compare.compare(base, copy.deepcopy(base))
        assert findings == []


class TestCompareCli:
    def _write(self, tmp_path, name, payloads):
        p = tmp_path / name
        p.write_text(json.dumps({"schema": 1, "benches": payloads}))
        return str(p)

    def test_exit_zero_on_match_and_one_on_regression(self, tmp_path,
                                                      capsys):
        base = self._write(tmp_path, "base.json", BASE)
        cur = self._write(tmp_path, "cur.json", _cur())
        assert compare.main(["--baseline", base, "--current", cur]) == 0
        assert "PASS" in capsys.readouterr().out
        bad = self._write(tmp_path, "bad.json",
                          _cur(lambda c: c["fig4"]["rows"][2].__setitem__(
                              "tuned_speedup", 0.5)))
        assert compare.main(["--baseline", base, "--current", bad]) == 1
        out = capsys.readouterr()
        assert "FAIL" in out.out and "tuned_speedup" in out.err

    def test_summary_file_and_current_dir(self, tmp_path, capsys):
        base = self._write(tmp_path, "base.json", BASE)
        out_dir = tmp_path / "bench-out"
        out_dir.mkdir()
        for key, payload in _cur().items():
            payload = dict(payload, bench=key)
            (out_dir / f"BENCH_{key}.json").write_text(json.dumps(payload))
        summary = tmp_path / "report.md"
        rc = compare.main(["--baseline", base, "--current", str(out_dir),
                           "--summary", str(summary)])
        assert rc == 0
        assert "Perf-regression gate" in summary.read_text()

    def test_usage_error_exit_two(self, tmp_path, capsys):
        rc = compare.main(["--baseline", str(tmp_path / "none.json"),
                           "--current", str(tmp_path)])
        assert rc == 2


_BASELINE = os.path.join(os.path.dirname(__file__), "..", "reports",
                         "BENCH_baseline.json")


@pytest.mark.skipif(not os.path.exists(_BASELINE),
                    reason="no committed gate baseline")
class TestCommittedBaseline:
    """Acceptance: zero exit on the committed baseline vs itself, non-zero
    on an injected 2x slowdown."""

    def test_self_compare_passes(self):
        assert compare.main(["--baseline", _BASELINE,
                             "--current", _BASELINE]) == 0

    def test_injected_2x_slowdown_fails(self, tmp_path):
        with open(_BASELINE) as f:
            data = json.load(f)
        injected = 0
        for payload in data["benches"].values():
            rows = payload.get("rows")
            groups = rows.values() if isinstance(rows, dict) else [rows]
            for rs in groups:
                for r in rs or []:
                    for k, v in list(r.items()):
                        if not isinstance(v, (int, float)) or \
                                isinstance(v, bool):
                            continue
                        if compare.is_gated(k) and \
                                common.metric_direction(k) == "higher":
                            r[k] = v / 2       # every tuned/ratio path 2x
                            injected += 1
        assert injected > 0, "baseline carries no gated metrics"
        doctored = tmp_path / "slow.json"
        doctored.write_text(json.dumps(data))
        assert compare.main(["--baseline", _BASELINE,
                             "--current", str(doctored)]) == 1

    def test_doubled_wall_us_fails_with_gate_absolute(self, tmp_path):
        with open(_BASELINE) as f:
            data = json.load(f)
        injected = 0
        for payload in data["benches"].values():
            rows = payload.get("rows")
            groups = rows.values() if isinstance(rows, dict) else [rows]
            for rs in groups:
                for r in rs or []:
                    if isinstance(r.get("wall_us"), (int, float)):
                        r["wall_us"] = r["wall_us"] * 2
                        injected += 1
        assert injected > 0
        doctored = tmp_path / "slow.json"
        doctored.write_text(json.dumps(data))
        assert compare.main(["--baseline", _BASELINE, "--current",
                             str(doctored), "--gate-absolute"]) == 1


class TestCommonHelpers:
    def test_metric_direction(self):
        assert common.metric_direction("wall_us") == "lower"
        assert common.metric_direction("cache_bytes_per_slot") == "lower"
        assert common.metric_direction("prefill_tok_s") == "higher"
        assert common.metric_direction("tuned_speedup") == "higher"
        assert common.metric_direction("slots_vs_bf16") == "higher"
        assert common.metric_direction("plan") is None
        assert common.metric_direction("w_bits") is None

    def test_record_and_row_case(self):
        r = common.record("tuned-vs-heuristic/lanes", tuned_speedup=1.2)
        assert common.row_case(r) == "tuned-vs-heuristic/lanes"
        assert common.row_case({"impl": "int16"}) == "impl=int16"
        assert common.row_case({"mode": "native", "w_bits": 2,
                                "a_bits": 2}) == \
            "mode=native|w_bits=2|a_bits=2"
        assert common.row_case({}, 7) == "row7"

    def test_wall_us_median_of_repeats(self):
        import jax.numpy as jnp

        us = common.wall_us(lambda: jnp.zeros(()), iters=1, warmup=1,
                            repeats=3, min_time_s=0.001)
        assert us > 0

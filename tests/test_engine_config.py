"""EngineConfig: the redesigned serving construction surface (DESIGN.md
§17) — validation at construction, the HBM-budget capacity rule as a
method, CLI/programmatic construction through one path, and the legacy
keyword deprecation shim on ServingEngine.
"""

import dataclasses
import warnings

import jax
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.models import lm
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import ServingEngine


def float_cfg(name="stablelm-1.6b"):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))


@pytest.fixture(scope="module")
def tiny():
    cfg = float_cfg()
    return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# Validation in __post_init__
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(max_batch=0), "max_batch"),
    (dict(max_len=0), "max_len"),
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(max_queue=0), "max_queue"),
    (dict(hbm_cache_budget=0), "hbm_cache_budget"),
    (dict(dense_store=True, packed=False), "dense_store"),
    (dict(autotune=True, packed=False), "autotune"),
])
def test_engine_config_validation_errors(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kw)


def test_engine_config_sampling_type_checked():
    with pytest.raises(TypeError, match="SamplingParams"):
        EngineConfig(sampling={"temperature": 1.0})


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="finite"):
        SamplingParams(temperature=float("nan"))
    assert SamplingParams(temperature=-1.0).greedy     # <= 0 means greedy


def test_engine_config_frozen():
    cfg = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.max_batch = 8


# ---------------------------------------------------------------------------
# Capacity rule (budget/slot math moved out of ServingEngine.__init__)
# ---------------------------------------------------------------------------

def test_slots_for_budget_math():
    assert EngineConfig(max_batch=3).slots_for(1000) == 3   # no budget
    c = EngineConfig(max_batch=1, hbm_cache_budget=4096)
    assert c.slots_for(1000) == 4
    with pytest.raises(ValueError, match="hbm_cache_budget"):
        c.slots_for(8192)                                   # < one slot


def test_engine_resolves_slots_from_budget(tiny):
    cfg, params = tiny
    from repro.serve.prepare import cache_bytes_per_slot
    per_slot = cache_bytes_per_slot(cfg, 32)
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=1, max_len=32, packed=False,
        hbm_cache_budget=3 * per_slot))
    assert eng.max_batch == 3
    assert eng.config.max_batch == 1        # config records the request


# ---------------------------------------------------------------------------
# One construction path: CLI from_args == programmatic
# ---------------------------------------------------------------------------

def test_from_args_matches_programmatic():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([
        "--arch", "stablelm-1.6b", "--reduced", "--max-batch", "3",
        "--max-len", "48", "--prefill-chunk", "8", "--max-queue", "5",
        "--temperature", "0.7", "--top-k", "4",
        "--hbm-cache-budget-mb", "0"])
    assert EngineConfig.from_args(args) == EngineConfig(
        max_batch=3, max_len=48, packed=True, prefill_chunk=8,
        max_queue=5,
        sampling=SamplingParams(temperature=0.7, top_k=4))


def test_from_args_zero_sentinels_map_to_none():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args(
        ["--arch", "stablelm-1.6b", "--no-packed"])
    c = EngineConfig.from_args(args)
    assert c.max_queue is None and c.hbm_cache_budget is None
    assert not c.packed


def test_cli_flags_are_grouped():
    """The api_redesign satellite: flags live in named argparse groups."""
    from repro.launch.serve import build_parser
    groups = {g.title for g in build_parser()._action_groups}
    assert {"engine", "sampling", "quantization", "parallelism",
            "fleet"} <= groups
    fleet = [g for g in build_parser()._action_groups
             if g.title == "fleet"][0]
    assert any("--data-parallel" in a.option_strings
               for a in fleet._group_actions)


# ---------------------------------------------------------------------------
# Legacy keyword shim (one release, DeprecationWarning)
# ---------------------------------------------------------------------------

def test_legacy_kwargs_warn_and_forward(tiny):
    cfg, params = tiny
    with pytest.warns(DeprecationWarning, match="EngineConfig"):
        eng = ServingEngine(cfg, params, max_batch=3, max_len=48,
                            packed=False, prefill_chunk=8, max_queue=2)
    assert eng.config == EngineConfig(
        max_batch=3, max_len=48, packed=False, prefill_chunk=8,
        max_queue=2)
    assert (eng.max_batch, eng.max_len, eng.prefill_chunk) == (3, 48, 8)


def test_legacy_greedy_flag_folds_into_sampling(tiny):
    cfg, params = tiny
    with pytest.warns(DeprecationWarning):
        eng = ServingEngine(cfg, params, max_len=32, packed=False,
                            greedy=False)
    assert eng.sampling == SamplingParams(temperature=1.0)


def test_legacy_prefill_chunk_clamps_like_before(tiny):
    """Old constructor clamped prefill_chunk to >= 1; the shim preserves
    that, while direct EngineConfig construction now raises."""
    cfg, params = tiny
    with pytest.warns(DeprecationWarning):
        eng = ServingEngine(cfg, params, max_len=32, packed=False,
                            prefill_chunk=0)
    assert eng.prefill_chunk == 1


def test_config_plus_legacy_kwargs_rejected(tiny):
    cfg, params = tiny
    with pytest.raises(TypeError, match="not both"):
        ServingEngine(cfg, params, config=EngineConfig(), max_batch=2)


def test_unknown_legacy_kwarg_rejected(tiny):
    cfg, params = tiny
    with pytest.raises(TypeError):
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            ServingEngine(cfg, params, batch_size=2)


def test_config_path_emits_no_deprecation(tiny):
    cfg, params = tiny
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=1, max_len=32, packed=False))
    assert eng.config.max_batch == 1

"""EngineConfig: the redesigned serving construction surface (DESIGN.md
§17) — validation at construction, the HBM-budget capacity rule as a
method, CLI/programmatic construction through one path, and the hard
removal of the legacy keyword surface on ServingEngine.
"""

import dataclasses
import warnings

import jax
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.models import lm
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import ServingEngine


def float_cfg(name="stablelm-1.6b"):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))


@pytest.fixture(scope="module")
def tiny():
    cfg = float_cfg()
    return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# Validation in __post_init__
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("kw,match", [
    (dict(max_batch=0), "max_batch"),
    (dict(max_len=0), "max_len"),
    (dict(prefill_chunk=0), "prefill_chunk"),
    (dict(max_queue=0), "max_queue"),
    (dict(hbm_cache_budget=0), "hbm_cache_budget"),
    (dict(dense_store=True, packed=False), "dense_store"),
    (dict(autotune=True, packed=False), "autotune"),
])
def test_engine_config_validation_errors(kw, match):
    with pytest.raises(ValueError, match=match):
        EngineConfig(**kw)


def test_engine_config_sampling_type_checked():
    with pytest.raises(TypeError, match="SamplingParams"):
        EngineConfig(sampling={"temperature": 1.0})


def test_sampling_params_validation():
    with pytest.raises(ValueError, match="top_k"):
        SamplingParams(top_k=-1)
    with pytest.raises(ValueError, match="finite"):
        SamplingParams(temperature=float("nan"))
    assert SamplingParams(temperature=-1.0).greedy     # <= 0 means greedy


def test_engine_config_frozen():
    cfg = EngineConfig()
    with pytest.raises(dataclasses.FrozenInstanceError):
        cfg.max_batch = 8


# ---------------------------------------------------------------------------
# Capacity rule (budget/slot math moved out of ServingEngine.__init__)
# ---------------------------------------------------------------------------

def test_slots_for_budget_math():
    assert EngineConfig(max_batch=3).slots_for(1000) == 3   # no budget
    c = EngineConfig(max_batch=1, hbm_cache_budget=4096)
    assert c.slots_for(1000) == 4
    with pytest.raises(ValueError, match="hbm_cache_budget"):
        c.slots_for(8192)                                   # < one slot


def test_engine_resolves_slots_from_budget(tiny):
    cfg, params = tiny
    from repro.serve.prepare import cache_bytes_per_slot
    per_slot = cache_bytes_per_slot(cfg, 32)
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=1, max_len=32, packed=False,
        hbm_cache_budget=3 * per_slot))
    assert eng.max_batch == 3
    assert eng.config.max_batch == 1        # config records the request


# ---------------------------------------------------------------------------
# One construction path: CLI from_args == programmatic
# ---------------------------------------------------------------------------

def test_from_args_matches_programmatic():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([
        "--arch", "stablelm-1.6b", "--reduced", "--max-batch", "3",
        "--max-len", "48", "--prefill-chunk", "8", "--max-queue", "5",
        "--temperature", "0.7", "--top-k", "4",
        "--hbm-cache-budget-mb", "0"])
    assert EngineConfig.from_args(args) == EngineConfig(
        max_batch=3, max_len=48, packed=True, prefill_chunk=8,
        max_queue=5,
        sampling=SamplingParams(temperature=0.7, top_k=4))


def test_pages_for_budget_math():
    """Paged twin of slots_for: the budget buys pages, floored at one
    worst-case slot's worth."""
    assert EngineConfig(max_batch=3).pages_for(100, 2) == 6   # no budget
    c = EngineConfig(max_batch=1, hbm_cache_budget=1000)
    assert c.pages_for(100, 2) == 10
    with pytest.raises(ValueError, match="hbm_cache_budget"):
        c.pages_for(600, 2)                # < one worst-case slot


def test_page_size_validated_at_construction():
    with pytest.raises(ValueError, match="page_size"):
        EngineConfig(page_size=0)


def test_from_args_paged_flags():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args([
        "--arch", "stablelm-1.6b", "--paged-kv", "--page-size", "32",
        "--no-prefix-sharing"])
    c = EngineConfig.from_args(args)
    assert c.paged and c.page_size == 32 and not c.prefix_sharing
    default = EngineConfig.from_args(
        build_parser().parse_args(["--arch", "stablelm-1.6b"]))
    assert not default.paged and default.prefix_sharing


def test_from_args_sub_megabyte_budget_is_not_unlimited():
    """A positive --hbm-cache-budget-mb must never silently become 'no
    budget' (the old `int(mb * 2**20) or None` truncation bug); only an
    explicit 0 / negative disables the budget."""
    from repro.launch.serve import build_parser

    def parse(mb):
        return EngineConfig.from_args(build_parser().parse_args(
            ["--arch", "stablelm-1.6b", "--hbm-cache-budget-mb", mb]))

    assert parse("0").hbm_cache_budget is None
    assert parse("-1").hbm_cache_budget is None
    assert parse("0.5").hbm_cache_budget == 512 * 1024
    with pytest.raises(ValueError, match="under one byte"):
        parse("0.0000001")


def test_from_args_zero_sentinels_map_to_none():
    from repro.launch.serve import build_parser
    args = build_parser().parse_args(
        ["--arch", "stablelm-1.6b", "--no-packed"])
    c = EngineConfig.from_args(args)
    assert c.max_queue is None and c.hbm_cache_budget is None
    assert not c.packed


def test_cli_flags_are_grouped():
    """The api_redesign satellite: flags live in named argparse groups."""
    from repro.launch.serve import build_parser
    groups = {g.title for g in build_parser()._action_groups}
    assert {"engine", "sampling", "quantization", "parallelism",
            "fleet"} <= groups
    fleet = [g for g in build_parser()._action_groups
             if g.title == "fleet"][0]
    assert any("--data-parallel" in a.option_strings
               for a in fleet._group_actions)


# ---------------------------------------------------------------------------
# Legacy keyword surface: shim removed after its one-release grace period
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("legacy_kw", [
    dict(max_batch=3, max_len=48),
    dict(greedy=False),
    dict(prefill_chunk=0),
    dict(batch_size=2),                 # unknown kwargs too — same error
])
def test_legacy_kwargs_raise_naming_engine_config(tiny, legacy_kw):
    """The PR 7 DeprecationWarning shim is gone: every engine keyword —
    known-legacy or unknown — is a TypeError pointing at EngineConfig."""
    cfg, params = tiny
    with pytest.raises(TypeError, match="EngineConfig"):
        ServingEngine(cfg, params, **legacy_kw)


def test_config_plus_legacy_kwargs_rejected(tiny):
    cfg, params = tiny
    with pytest.raises(TypeError, match="EngineConfig"):
        ServingEngine(cfg, params, config=EngineConfig(), max_batch=2)


def test_from_legacy_kwargs_is_gone():
    assert not hasattr(EngineConfig, "from_legacy_kwargs")


def test_config_path_emits_no_deprecation(tiny):
    cfg, params = tiny
    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=1, max_len=32, packed=False))
    assert eng.config.max_batch == 1

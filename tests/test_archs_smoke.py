"""Per-architecture smoke tests: reduced config, one forward + one grad step
on CPU, asserting output shapes and NaN-freedom (assignment requirement)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import lm


def make_batch(cfg, rng, b=2, s=16):
    batch = {"tokens": jnp.asarray(
        rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    labels = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)
    if cfg.frontend == "vision":
        si = 4
        batch["embeds"] = jnp.asarray(
            rng.normal(size=(b, si, cfg.frontend_dim)), jnp.float32)
        total = si + s
        pos = jnp.broadcast_to(jnp.arange(total)[None], (b, total))
        batch["positions"] = pos
        batch["positions3"] = jnp.broadcast_to(pos[None], (3, b, total))
        labels = jnp.pad(labels, ((0, 0), (si, 0)), constant_values=-1)
    if cfg.frontend == "audio":
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(size=(b, 8, cfg.frontend_dim)), jnp.float32)
    batch["labels"] = labels
    return batch


LM_ARCHS = [n for n in configs.ARCH_NAMES]


@pytest.mark.parametrize("name", LM_ARCHS)
def test_forward_shapes_and_finiteness(name):
    cfg = configs.get_config(name, reduced=True)
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    rng = np.random.default_rng(0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, rng)
    logits, aux, _ = lm.forward(params, cfg, batch, quant_mode="qat")
    b, s = batch["labels"].shape
    assert logits.shape == (b, s, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, ce = lm.loss_fn(logits, batch["labels"], aux)
    assert bool(jnp.isfinite(loss))


@pytest.mark.parametrize("name", LM_ARCHS)
def test_one_grad_step_no_nans(name):
    cfg = configs.get_config(name, reduced=True)
    cfg = cfg.replace(param_dtype="float32", compute_dtype="float32")
    rng = np.random.default_rng(1)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    batch = make_batch(cfg, rng)

    def loss(p):
        logits, aux, _ = lm.forward(p, cfg, batch, quant_mode="qat")
        return lm.loss_fn(logits, batch["labels"], aux)[0]

    val, grads = jax.value_and_grad(loss)(params)
    assert bool(jnp.isfinite(val))
    leaves = jax.tree.leaves(grads)
    assert leaves, "no grads produced"
    for g in leaves:
        if isinstance(g, jnp.ndarray) and jnp.issubdtype(g.dtype,
                                                         jnp.floating):
            assert bool(jnp.all(jnp.isfinite(g)))


def test_sparq_cnn_smoke():
    from repro.models import cnn
    cfg = configs.get_config("sparq-cnn", reduced=True)
    rng = np.random.default_rng(2)
    params = cnn.init_params(jax.random.PRNGKey(2), cfg)
    x = jnp.asarray(rng.normal(size=(2, cfg.cnn_input_hw, cfg.cnn_input_hw,
                                     3)), jnp.float32)
    for mode in ("none", "qat", "packed"):
        logits = cnn.forward(params, cfg, x, quant_mode=mode, backend="xla")
        assert logits.shape == (2, cfg.cnn_num_classes)
        assert bool(jnp.all(jnp.isfinite(logits))), mode


def test_param_counts_match_analytic():
    """init_params parameter count ~= ModelConfig.param_counts() (±5%)."""
    for name in ("stablelm-1.6b", "mixtral-8x7b"):
        cfg = configs.get_config(name, reduced=True)
        params = lm.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params)
                     if hasattr(x, "size"))
        analytic = cfg.param_counts()["total"]
        # analytic excludes norms/steps/routers; allow slack
        assert abs(actual - analytic) / analytic < 0.25, (name, actual,
                                                          analytic)

"""Dry-run machinery: lower a production cell in a 512-device subprocess,
parse collective bytes from compiled HLO, applicability matrix."""

import subprocess
import sys

import pytest

from repro import configs
from repro.launch import shapes as shp
from test_pipeline import subprocess_env


class TestApplicability:
    def test_cell_count(self):
        live = sum(shp.cell_is_live(a, s)[0]
                   for a in configs.ARCH_NAMES for s in shp.SHAPES)
        skipped = 40 - live
        assert live == 34 and skipped == 6   # DESIGN.md §5

    def test_long_context_archs_run_500k(self):
        for a in shp.LONG_CONTEXT_ARCHS:
            assert shp.cell_is_live(a, "long_500k")[0]

    def test_full_attention_archs_skip_500k(self):
        assert not shp.cell_is_live("stablelm-1.6b", "long_500k")[0]
        assert not shp.cell_is_live("qwen2-vl-2b", "long_500k")[0]


class TestInputSpecs:
    @pytest.mark.parametrize("arch", configs.ARCH_NAMES)
    def test_specs_exist_for_all_live_cells(self, arch):
        cfg = configs.get_config(arch)
        for s in shp.SHAPES:
            if not shp.cell_is_live(arch, s)[0]:
                continue
            specs = shp.input_specs(cfg, s)
            assert specs, (arch, s)

    def test_train_spec_shapes(self):
        cfg = configs.get_config("stablelm-1.6b")
        b = shp.input_specs(cfg, "train_4k")
        assert b["tokens"].shape == (256, 4096)

    def test_decode_spec_has_full_length_cache(self):
        cfg = configs.get_config("granite-3-8b")
        specs = shp.input_specs(cfg, "decode_32k")
        k = specs["caches"][0]["attn"]["k"]
        assert k.shape == (128, 32768, 8, 128)

    def test_swa_decode_cache_is_window_bounded(self):
        cfg = configs.get_config("mixtral-8x7b")
        specs = shp.input_specs(cfg, "long_500k")
        k = specs["caches"][0]["attn"]["k"]
        assert k.shape[1] == 4096   # ring buffer, not 524288


@pytest.mark.slow
def test_lower_one_cell_subprocess():
    """End-to-end: 512 fake devices, production mesh, full lowering of one
    live cell (the compile sweep covers the rest)."""
    r = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch",
         "seamless-m4t-medium", "--shape", "decode_32k", "--lower-only"],
        capture_output=True, text=True, timeout=900, env=subprocess_env())
    assert "LOWER_OK" in r.stdout, (r.stdout[-1500:], r.stderr[-1500:])


class TestCollectiveParse:
    def test_parse_known_lines(self):
        from repro.roofline import analysis
        hlo = """
  %all-reduce.1 = bf16[16,4096]{1,0} all-reduce(%add.5), channel_id=1, replica_groups=[16,16]<=[256], use_global_device_ids=true, to_apply=%sum
  %ag = f32[256,1024]{1,0} all-gather(%p0), channel_id=2, replica_groups=[16,16]<=[256], dimensions={0}
  %rs = f32[16,64]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups=[4,4]<=[16], to_apply=%sum
"""
        out = analysis.collective_bytes(hlo)
        assert out["counts"]["all-reduce"] == 1
        assert out["all-reduce"] == 16 * 4096 * 2
        assert out["all-gather"] == 256 * 1024 * 4 // 16
        assert out["reduce-scatter"] == 16 * 64 * 4 * 4

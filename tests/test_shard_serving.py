"""Mesh-native serving (serve/shard.ShardPlan, DESIGN.md §15).

The tentpole invariant: on a forced multi-device CPU mesh
(XLA_FLAGS=--xla_force_host_platform_device_count=4, the `shard` CI lane),
the tensor-parallel packed ServingEngine produces token-for-token identical
output to the single-device engine — the packed integer algebra is exact,
column-parallel N-sharding keeps every int32 word / int16 lane shard-local,
and the kv-head-sharded (possibly sub-byte packed) cache quantizes and
packs per head.  A mesh=1 engine is behaviorally unchanged.

Multi-device tests skip below 4 devices so the plain tier-1 run stays
green on 1-device hosts; the warning/spec tests run anywhere.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch.mesh import make_host_mesh, make_serving_mesh
from repro.models import lm
from repro.parallel import sharding
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine
from repro.serve.shard import ShardPlan

pytestmark = pytest.mark.shard

needs_mesh = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=4)")


def packed_cfg(name="stablelm-1.6b", w_bits=2, kv_bits=4, **kw):
    lane = "int32" if w_bits >= 4 else "int16"   # w4a4 overflows int16 lanes
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=w_bits, a_bits=w_bits,
                          lane_dtype=lane, kv_bits=kv_bits), **kw)


def run_engine(cfg, params, mesh, *, prompts, max_new=5, **kw):
    eng = ServingEngine(cfg, params, mesh=mesh, config=EngineConfig(
        max_batch=2, max_len=32, packed=True, prefill_chunk=4, **kw))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    return eng, {r.uid: tuple(r.output) for r in eng.run_to_completion()}


def seeded_prompts(cfg, lens=(7, 3, 11), seed=1):
    rng = np.random.default_rng(seed)
    return [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]


# ---------------------------------------------------------------------------
# Tentpole: token-for-token identity, sharded vs single-device
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("w_bits,kv_bits", [(2, 0), (2, 4), (4, 0), (4, 4)])
def test_sharded_engine_token_identical(w_bits, kv_bits):
    """4-way TP packed engine == single-device engine, token for token,
    across packed 2/4-bit weights x kv_bits {16, 4} (staggered admission
    included: three prompts through two slots)."""
    cfg = packed_cfg(w_bits=w_bits, kv_bits=kv_bits)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = seeded_prompts(cfg)
    _, single = run_engine(cfg, params, None, prompts=prompts)
    eng, sharded = run_engine(cfg, params, make_serving_mesh(4),
                              prompts=prompts)
    assert sharded == single
    # and the layout actually sharded: column-parallel packed weights,
    # kv-head-sharded cache (words axis intact for packed caches)
    wq = eng.params["layers"][0]["attn"]["q"]["w_packed"]
    assert wq.sharding.spec == P(None, "model")
    assert wq.addressable_shards[0].data.shape == (wq.shape[0],
                                                   wq.shape[1] // 4)
    kc = eng.caches[0]["attn"]["k"]
    assert kc.sharding.spec == P(None, None, "model") \
        or kc.sharding.spec == P(None, None, "model", None)
    assert kc.addressable_shards[0].data.shape[2] == kc.shape[2] // 4


@needs_mesh
def test_sharded_engine_gqa_indivisible_heads_replicate():
    """granite (reduced: 2 kv heads) on a 4-way mesh: the divisibility
    guard replicates the cache head axis rather than producing an invalid
    sharding, and output stays token-identical."""
    cfg = packed_cfg("granite-3-8b", kv_bits=4)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    prompts = seeded_prompts(cfg, seed=2)
    _, single = run_engine(cfg, params, None, prompts=prompts)
    eng, sharded = run_engine(cfg, params, make_serving_mesh(4),
                              prompts=prompts)
    assert sharded == single
    kc = eng.caches[0]["attn"]["k"]
    assert all(a is None for a in kc.sharding.spec)


@needs_mesh
def test_mesh1_engine_behaviorally_unchanged():
    """A mesh with model=1 degrades to the single-device layout (every
    spec guards to replicated) and generates identical tokens."""
    cfg = packed_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = seeded_prompts(cfg)
    _, single = run_engine(cfg, params, None, prompts=prompts)
    eng, mesh1 = run_engine(cfg, params, make_serving_mesh(1),
                            prompts=prompts)
    assert mesh1 == single
    assert eng.shard_plan.model_shards == 1


@needs_mesh
def test_sharded_engine_metrics_and_reports():
    """The sharded engine's metrics report carries the new per-request
    latency distributions and the capacity report names the shard plan."""
    cfg = packed_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng, _ = run_engine(cfg, params, make_serving_mesh(4),
                        prompts=seeded_prompts(cfg), max_new=3)
    rep = eng.metrics.report()
    assert len(eng.metrics.ttft_s) == 3          # one sample per request
    assert len(eng.metrics.tpot_s) == 3
    assert rep["ttft_s"]["p95"] >= rep["ttft_s"]["p50"] > 0
    assert rep["tpot_s"]["mean"] > 0
    cap = eng.capacity_report()
    assert cap["shard_plan"]["model_shards"] == 4
    assert cap["shard_plan"]["mesh"] == {"data": 1, "model": 4}


# ---------------------------------------------------------------------------
# cache_shardings over quantized caches (satellite)
# ---------------------------------------------------------------------------

@needs_mesh
@pytest.mark.parametrize("kv_bits", [4, 2])
def test_cache_shardings_quantized_kv_head_shard(kv_bits):
    """cache_shardings(kv_head_shard=True) on a real 4-device host mesh
    over sub-byte packed caches: K/V int32 words and the per-(pos, head)
    scale planes shard the kv-head axis, placement round-trips values,
    and every shard holds whole words."""
    cfg = packed_cfg(kv_bits=kv_bits)
    mesh = make_host_mesh(data=1, model=4)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].dtype == jnp.int32    # packed words
    sh = sharding.cache_shardings(caches, cfg, mesh, 2, kv_head_shard=True)
    attn = sh[0]["attn"]
    bp = ("data",)       # size-1 batch axis on the (1, 4) serving mesh
    assert attn["k"].spec == P(bp, None, "model", None)
    assert attn["v"].spec == P(bp, None, "model", None)
    assert attn["k_scale"].spec == P(bp, None, "model")
    assert attn["v_scale"].spec == P(bp, None, "model")
    placed = jax.tree.map(
        lambda c, s: None if c is None else jax.device_put(c, s),
        caches, sh, is_leaf=lambda x: x is None)
    kvh, words = caches[0]["attn"]["k"].shape[2:]
    shard_shape = placed[0]["attn"]["k"].addressable_shards[0].data.shape
    assert shard_shape[2] == kvh // 4 and shard_shape[3] == words
    np.testing.assert_array_equal(np.asarray(placed[0]["attn"]["k"]),
                                  np.asarray(caches[0]["attn"]["k"]))


@needs_mesh
def test_cache_shardings_quantized_scales_follow_heads():
    """Writing through the sharded quantized cache keeps values identical
    to the unsharded write (quantize/pack is per-(pos, head) local)."""
    from repro.models import attention
    cfg = packed_cfg(kv_bits=4)
    mesh = make_host_mesh(data=1, model=4)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)[0]["attn"]
    sh = sharding.cache_shardings(
        [{"attn": caches}], cfg, mesh, 2, kv_head_shard=True)[0]["attn"]
    placed = jax.tree.map(jax.device_put, caches, sh)
    rng = np.random.default_rng(3)
    hd = cfg.resolved_head_dim
    k = jnp.asarray(rng.normal(size=(2, 1, cfg.num_kv_heads, hd)),
                    jnp.float32)
    v = jnp.asarray(rng.normal(size=(2, 1, cfg.num_kv_heads, hd)),
                    jnp.float32)
    ref = attention._cache_write(caches, k, v, 0, 4)
    got = attention._cache_write(placed, k, v, 0, 4)
    for key in ("k", "v", "k_scale", "v_scale"):
        np.testing.assert_array_equal(np.asarray(got[key]),
                                      np.asarray(ref[key]))


# ---------------------------------------------------------------------------
# ShardPlan specs (no mesh-size requirement beyond what the host has)
# ---------------------------------------------------------------------------

@needs_mesh
def test_shard_plan_param_specs():
    """Packed serving tree: w_packed/w_dense/bias/col_sums shard the
    output axis; quant scalars and unpacked leaves replicate; indivisible
    dims guard to replicated."""
    from repro.serve.prepare import prepare_serving_params
    cfg = packed_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    packed = prepare_serving_params(params, cfg)
    plan = ShardPlan(make_serving_mesh(4))
    sh = plan.param_shardings(packed)
    q = sh["layers"][0]["attn"]["q"]
    assert q["w_packed"].spec == P(None, "model")
    assert q["col_sums"].spec == P("model")
    assert q["w_scale"].spec == P()
    assert sh["embed"]["table"].spec == P(None, None)
    # local-shape planning: the per-shard matmul plans against N/4
    n = packed["layers"][0]["attn"]["q"]["w_packed"].shape[-1]
    assert plan.local_out(n) == n // 4
    assert plan.local_out(n - 1) == n - 1          # indivisible: unsharded


@needs_mesh
def test_sharded_plans_cover_dispatch_signatures():
    """Under a ShardPlan, build_layer_plans keeps per-shard local plans as
    the primary entries AND pre-memoizes the global-width signatures the
    GSPMD-jitted steps re-plan with at trace time: the plan the dispatch
    path looks up must be the exact init-built ``@global`` object (the
    memoized planner guarantees identity), so autotune warm-tuning covers
    what execution actually reads."""
    from repro.core.packing import PackSpec
    from repro.kernels import plan as plan_lib
    cfg = packed_cfg()
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, mesh=make_serving_mesh(4),
                        config=EngineConfig(max_batch=2, max_len=32,
                                            packed=True, prefill_chunk=4))
    spec = PackSpec.from_config(cfg.quant)
    node = eng.params["layers"][0]["attn"]["q"]
    kp, n_global = node["w_packed"].shape      # sharded arrays: global shape
    assert "layers[0]/attn/q@global" in eng.plans
    # what ops.quantized_linear(plan=None) looks up inside the jitted
    # decode step: rows = max_batch, global n, backend 'auto' (kwargs
    # spelled exactly as quantized_linear spells them — lru_cache keys
    # include explicit kwargs)
    dispatched = plan_lib.plan_packed_matmul(
        2, kp, n_global, spec, backend="auto", weight_store="lanes",
        k_full=None)
    assert dispatched is eng.plans["layers[0]/attn/q@global"]
    prefill = plan_lib.plan_packed_matmul(
        2 * 4, kp, n_global, spec, backend="auto", weight_store="lanes",
        k_full=None)
    assert prefill is eng.plans["layers[0]/attn/q@global@prefill"]


def test_host_mesh_clamp_warns():
    """make_host_mesh names requested vs actual shape instead of clamping
    silently (satellite); feasible requests stay silent."""
    n = len(jax.devices())
    with pytest.warns(UserWarning, match=rf"requested \(data={2 * n}, "
                                         rf"model=4\).*has {n}"):
        make_host_mesh(data=2 * n, model=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        mesh = make_host_mesh(data=1, model=1)     # always feasible
    assert dict(mesh.shape) == {"data": 1, "model": 1}


def test_serving_mesh_validates():
    with pytest.raises(ValueError):
        make_serving_mesh(0)

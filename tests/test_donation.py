"""KV-cache buffer donation in the jitted serving steps (DESIGN.md §20).

`jitted_serving_steps` / `jitted_speculative_steps` donate the cache
pytree (arg 1): every engine call site reassigns its caches from the
step's return, so the old ring buffers are dead on entry and XLA may
scatter the new tokens in place instead of copying the whole cache each
step.  Guarded here: the output ring aliases the input's buffer (same
``unsafe_buffer_pointer``), the donated input is actually consumed, XLA
emits no donation-mismatch warning, and the engine's tokens are
unchanged from the never-donated direct-call path.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine


def kv_cfg(kv_bits=0, name="stablelm-1.6b", **kw):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False, kv_bits=kv_bits), **kw)


def _cache_pointers(caches):
    return {ptr for layer in caches for buf in layer["attn"].values()
            for ptr in [buf.unsafe_buffer_pointer()]}


@pytest.mark.parametrize("kv_bits", [0, 4])
def test_decode_step_updates_cache_in_place(kv_bits):
    """The decode step's output cache reuses the donated input buffers —
    the per-step whole-cache copy is gone — and the donated input is
    consumed (accessing it afterwards raises)."""
    cfg = kv_cfg(kv_bits)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    decode, _ = steps_lib.jitted_serving_steps(cfg)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    batch = {"tokens": jnp.ones((2, 1), jnp.int32)}

    # warm up the trace on a throwaway cache so compile-time effects and
    # the first-call copy (donation needs a committed layout) are done
    _, caches = decode(params, caches, batch, jnp.int32(0))

    before = _cache_pointers(caches)
    with warnings.catch_warnings():
        warnings.simplefilter("error")       # donation mismatch warns
        _, out = decode(params, caches, batch, jnp.int32(1))
    after = _cache_pointers(out)
    assert before == after, "decode step copied the cache instead of " \
                            "updating the donated buffers in place"
    with pytest.raises(RuntimeError):
        jax.block_until_ready(caches[0]["attn"]["k"])


def test_prefill_chunk_step_donates_too():
    cfg = kv_cfg(4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    _, prefill = steps_lib.jitted_serving_steps(cfg)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    batch = {"tokens": jnp.ones((2, 4), jnp.int32)}
    idx = jnp.zeros((2,), jnp.int32)
    valid = jnp.full((2,), 4, jnp.int32)
    _, caches = prefill(params, caches, batch, idx, valid)
    before = _cache_pointers(caches)
    _, out = prefill(params, caches, batch, idx + 4, valid)
    assert _cache_pointers(out) == before


def test_engine_tokens_unchanged_by_donation():
    """Greedy outputs through the donating jitted steps equal a manual
    never-donated replay of the same requests."""
    cfg = kv_cfg(2)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(11)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (9, 14)]
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=32, packed=False, prefill_chunk=8))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    got = {r.uid: tuple(r.output) for r in eng.run_to_completion()}

    # manual replay with UNjitted steps (nothing donated, nothing shared)
    decode = steps_lib.make_decode_step(cfg)
    want = {}
    for uid, prompt in enumerate(prompts):
        caches = lm.init_caches(cfg, 1, 32, dtype=jnp.float32)
        tok, out = None, []
        for pos in range(len(prompt) + 3):
            feed = prompt[pos] if pos < len(prompt) else tok
            logits, caches = decode(params, caches,
                                    {"tokens": jnp.full((1, 1), feed,
                                                        jnp.int32)},
                                    jnp.int32(pos))
            tok = int(jnp.argmax(logits[0]))
            if pos >= len(prompt) - 1:
                out.append(tok)
        want[uid] = tuple(out)
    assert got == want

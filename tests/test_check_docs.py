"""tools/check_docs.py — the CI docs link-checker (doc-rot gate)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "tools"))

import check_docs  # noqa: E402


class TestSlugs:
    def test_github_slug_rules(self):
        assert check_docs.github_slug("§19 Speculative decoding") == \
            "19-speculative-decoding"
        assert check_docs.github_slug(
            "§2 vmacsr → MXU-tile epilogue mapping") == \
            "2-vmacsr--mxu-tile-epilogue-mapping"
        assert check_docs.github_slug("Packing algebra (P1/P4)") == \
            "packing-algebra-p1p4"

    def test_duplicate_headings_get_github_suffixes(self):
        slugs = check_docs.heading_slugs("# Same\n\n# Same\n")
        assert slugs == {"same", "same-1"}

    def test_fenced_code_blocks_are_not_headings(self):
        text = "# Real\n\n```\n# not a heading\n```\n"
        assert check_docs.heading_slugs(text) == {"real"}


class TestRepoDocs:
    def test_committed_docs_are_rot_free(self, capsys):
        """Acceptance: the checked-in front-door docs pass — anchors,
        file links, backticked code paths, and §N citations across
        src/tests/benchmarks/tools all resolve."""
        assert check_docs.main([]) == 0

    def test_injected_rot_fails(self, tmp_path, capsys):
        (tmp_path / "other.md").write_text("# Only heading\n")
        bad = tmp_path / "bad.md"
        bad.write_text(
            "[a](other.md#no-such-anchor)\n"
            "[b](missing/file.py)\n"
            "`serve/nonexistent_module.py`\n"
            # built via chr() so THIS source file (also scanned by the
            # checker's tests/*.py sweep) doesn't cite a bogus section
            "DESIGN.md " + chr(0xA7) + "99\n")
        rel = os.path.relpath(bad, check_docs.ROOT)
        assert check_docs.main([rel]) == 1
        err = capsys.readouterr().err
        for needle in ("broken anchor", "broken link target",
                       "does not exist", "no such section"):
            assert needle in err

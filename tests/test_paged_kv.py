"""Paged, prefix-sharing sub-byte KV cache (DESIGN.md §18).

PagePool bookkeeping (refcounts, all-or-nothing alloc, radix prefix
index, LRU leaf eviction, meta round-trip), the page-size/word-packing
divisibility rule, and the engine-level invariants: block-table decode is
token-for-token identical to the slot-contiguous cache across kv_bits, a
fixed HBM budget admits >= 2x the logical slots on a shared-prefix
workload, and Router drain/restore carries the warm prefix cache across
the checkpoint boundary.

The 4-device tensor-parallel identity test rides the `shard` CI lane
(forced multi-device CPU host) and skips below 4 devices; the wide
kv_bits sweep is `slow` (nightly).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch.mesh import make_serving_mesh
from repro.models import lm
from repro.serve import pages
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine
from repro.serve.prepare import cache_bytes_per_slot
from repro.serve.router import Router


def kv_cfg(kv_bits=0, name="stablelm-1.6b", **kw):
    return configs.get_config(name, reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False, kv_bits=kv_bits), **kw)


@pytest.fixture(scope="module")
def tiny():
    cfg = kv_cfg(4)
    return cfg, lm.init_params(jax.random.PRNGKey(0), cfg)


# ---------------------------------------------------------------------------
# Page-size granularity (the sub-byte wrinkle)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits,gran", [(0, 1), (8, 1), (4, 8), (2, 16)])
def test_page_granularity(bits, gran):
    assert pages.page_granularity(bits) == gran


@pytest.mark.parametrize("ps,bits,ok", [
    (16, 0, True), (16, 8, True), (16, 4, True), (16, 2, True),
    (8, 4, True), (8, 2, False), (12, 4, False), (1, 0, True),
])
def test_validate_page_size(ps, bits, ok):
    if ok:
        pages.validate_page_size(ps, bits)
    else:
        with pytest.raises(ValueError, match="word-packing tail"):
            pages.validate_page_size(ps, bits)
    with pytest.raises(ValueError, match="page_size"):
        pages.validate_page_size(0, bits)


# ---------------------------------------------------------------------------
# PagePool: physical pages
# ---------------------------------------------------------------------------

def test_alloc_all_or_nothing_and_refcounts():
    pool = pages.PagePool(num_pages=4, page_size=4)
    got = pool.alloc(3)
    assert len(got) == 3 and all(pool.ref[p] == 1 for p in got)
    # all-or-nothing: a too-big request takes NOTHING
    before = pool.report()["free_pages"]
    assert pool.alloc(2) is None
    assert pool.report()["free_pages"] == before == 1

    p = got[0]
    pool.retain(p)
    assert pool.is_shared(p) and pool.ref[p] == 2
    pool.release(p)
    assert not pool.is_shared(p)
    pool.release(p)                       # ref 0 -> back on the free list
    assert pool.report()["free_pages"] == 2
    with pytest.raises(RuntimeError, match="over-released"):
        pool.release(p)


def test_pool_constructor_validates():
    with pytest.raises(ValueError, match="num_pages"):
        pages.PagePool(0, 4)
    with pytest.raises(ValueError, match="word-packing tail"):
        pages.PagePool(4, 4, kv_bits=2)   # 2-bit words hold 16 values


# ---------------------------------------------------------------------------
# PagePool: prefix index
# ---------------------------------------------------------------------------

def test_register_and_match_prefix_full_and_partial():
    pool = pages.PagePool(num_pages=8, page_size=4)
    toks = list(range(100, 110))          # 10 tokens: 2 full pages + tail 2
    held = pool.alloc(3)
    assert pool.register_prefix(toks, held) == 3
    assert all(pool.ref[p] == 2 and pool.is_immutable(p) for p in held)

    n, hits = pool.match_prefix(toks)
    assert n == 10
    assert hits == [(held[0], 4), (held[1], 4), (held[2], 2)]

    # divergence mid-page: common head of the second chunk only
    n, hits = pool.match_prefix(toks[:5] + [999] * 5)
    assert n == 5 and hits == [(held[0], 4), (held[1], 1)]

    # max_tokens caps the walk inside the first page
    n, hits = pool.match_prefix(toks, max_tokens=3)
    assert n == 3 and hits == [(held[0], 3)]

    # a second registration of the same tokens is a no-op (hash-consed)
    dup = pool.alloc(3)
    assert pool.register_prefix(toks, dup) == 0
    assert all(pool.ref[p] == 1 for p in dup)


def test_eviction_is_lru_and_leaf_only():
    pool = pages.PagePool(num_pages=2, page_size=2)
    (a, b) = pool.alloc(2)
    pool.register_prefix([1, 2, 3, 4], [a, b])   # chain: a -> b
    pool.release(a)
    pool.release(b)                       # index-only now (ref 1 each)
    assert pool.report() == pool.report()  # sanity: report is pure
    assert pool.report()["cached_prefix_pages"] == 2

    # pressure: must evict the LEAF (b) first even though a is older
    got = pool.alloc(1)
    assert got == [b]
    assert pool.evicted_pages == 1
    n, _ = pool.match_prefix([1, 2, 3, 4])
    assert n == 2                          # parent chunk still cached
    got2 = pool.alloc(1)                   # now the orphaned parent goes
    assert got2 == [a] and pool.evicted_pages == 2
    assert pool.match_prefix([1, 2]) == (0, [])

    # pages shared with a live slot are never eviction victims
    pool2 = pages.PagePool(num_pages=2, page_size=2)
    (c, d) = pool2.alloc(2)
    pool2.register_prefix([5, 6], [c])     # ref(c) == 2: slot + index
    assert pool2.alloc(1) is None


def test_lru_respects_match_touch():
    pool = pages.PagePool(num_pages=3, page_size=2)
    (a,) = pool.alloc(1)
    pool.register_prefix([1, 2], [a])
    (b,) = pool.alloc(1)
    pool.register_prefix([3, 4], [b])
    pool.release(a)
    pool.release(b)
    pool.match_prefix([1, 2])              # a becomes most-recently-used
    (c,) = pool.alloc(1)
    pool.release(c)                        # free page consumed and returned
    # next pressure eviction takes b, the least recently touched leaf
    pool.alloc(2)
    assert pool.match_prefix([1, 2])[0] == 2
    assert pool.match_prefix([3, 4])[0] == 0


def test_pool_meta_round_trip():
    pool = pages.PagePool(num_pages=6, page_size=8, kv_bits=4)
    held = pool.alloc(3)
    toks = list(range(18))                 # 2 full pages + tail 2
    pool.register_prefix(toks, held)
    pool.release(held[2])                  # tail leaf: index-only
    pool.prefix_hits, pool.prefix_hit_tokens, pool.cow_copies = 2, 9, 1

    clone = pages.PagePool.from_meta(pool.export_meta())
    assert clone.report() == pool.report()
    assert clone.match_prefix(toks) == pool.match_prefix(toks)
    assert list(clone._free) == list(pool._free)
    assert (clone.ref == pool.ref).all()
    # the clock resumes past every restored stamp: a fresh touch on the
    # restored index must win any subsequent LRU comparison
    clone.match_prefix(toks[:4])
    node = clone._node_of_page[held[0]]
    assert all(node.stamp >= n.stamp
               for n in clone._node_of_page.values())


def test_copy_page_copies_attn_leaves_only():
    caches = [{
        "attn": {"k": jnp.arange(12, dtype=jnp.int32).reshape(3, 2, 2),
                 "k_scale": jnp.ones((3, 2), jnp.bfloat16) * 2},
        "recurrent": {"state": jnp.zeros((2, 4))},
    }]
    out = pages.copy_page(caches, src=0, dst=2)
    np.testing.assert_array_equal(np.asarray(out[0]["attn"]["k"][2]),
                                  np.asarray(caches[0]["attn"]["k"][0]))
    np.testing.assert_array_equal(np.asarray(out[0]["attn"]["k"][1]),
                                  np.asarray(caches[0]["attn"]["k"][1]))
    assert out[0]["recurrent"]["state"] is caches[0]["recurrent"]["state"]


# ---------------------------------------------------------------------------
# Engine: paged == unpaged, token for token
# ---------------------------------------------------------------------------

def shared_prefix_prompts(cfg, seed=11):
    """Prompt set exercising the whole sharing surface: full-page match,
    partial-tail match (COW on divergence), page-crossing prompts, and an
    unrelated request."""
    rng = np.random.default_rng(seed)
    base = rng.integers(0, cfg.vocab_size, 20).astype(np.int32)
    other = rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
    return [
        base[:18],                          # registers 2 pages (16 + tail 2)
        np.concatenate([base[:16], other]),  # shares exactly page 0
        rng.integers(0, cfg.vocab_size, 7).astype(np.int32),   # no sharing
        base[:20],                          # partial-tail match -> COW
    ]


def run_engine(cfg, params, prompts, *, paged, mesh=None, max_new=4):
    eng = ServingEngine(cfg, params, mesh=mesh, config=EngineConfig(
        max_batch=2, max_len=48, packed=False, prefill_chunk=8,
        paged=paged, page_size=16))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=max_new))
    out = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
    return out, eng


@pytest.mark.parametrize("kv_bits", [0, 4, 2])
def test_paged_identity_across_kv_bits(kv_bits):
    """The acceptance bar: block-table indirection is invisible in the
    tokens at bf16 and both sub-byte widths, while prefix hits and COW
    actually fire along the way."""
    cfg = kv_cfg(kv_bits)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = shared_prefix_prompts(cfg)
    want, _ = run_engine(cfg, params, prompts, paged=False)
    got, eng = run_engine(cfg, params, prompts, paged=True)
    assert got == want
    rep = eng.capacity_report()
    assert rep["paged"] and rep["prefix_sharing"]
    assert rep["prefix_hit_tokens"] >= 16    # page-0 reuse at minimum
    assert rep["cow_copies"] >= 1            # partial-tail divergence
    assert rep["pages_per_slot"] == 3        # ceil(48 / 16)


def test_paged_identity_without_sharing():
    """prefix_sharing=False still pages (pure indirection, no radix index)
    and still matches the unpaged engine."""
    cfg = kv_cfg(4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = shared_prefix_prompts(cfg)
    want, _ = run_engine(cfg, params, prompts, paged=False)
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=48, packed=False, prefill_chunk=8,
        paged=True, page_size=16, prefix_sharing=False))
    for i, p in enumerate(prompts):
        assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    got = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
    assert got == want
    rep = eng.capacity_report()
    assert not rep["prefix_sharing"] and rep["prefix_hit_tokens"] == 0


@pytest.mark.slow
@pytest.mark.parametrize("kv_bits", [0, 8, 4, 2])
def test_paged_identity_sweep_nightly(kv_bits):
    """Nightly-wide paged-vs-unpaged sweep: more requests than the pool
    holds at once, so admission backpressure, retirement recycling, and
    prefix-leaf eviction all run inside the identity check."""
    cfg = kv_cfg(kv_bits)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    rng = np.random.default_rng(3)
    base = rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
    prompts = [np.concatenate([base[:16 * (1 + i % 2)],
                               rng.integers(0, cfg.vocab_size, 3 + i)
                                  .astype(np.int32)])
               for i in range(6)]
    want, _ = run_engine(cfg, params, prompts, paged=False, max_new=6)
    got, eng = run_engine(cfg, params, prompts, paged=True, max_new=6)
    assert got == want
    assert eng.capacity_report()["prefix_hit_tokens"] > 0


needs_tp4 = pytest.mark.skipif(
    len(jax.devices()) < 4,
    reason="needs 4 devices for a model=4 mesh "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@pytest.mark.shard
@needs_tp4
def test_paged_identity_tensor_parallel():
    """Under a model=4 mesh the page pool's kv-head axis shards while the
    page axis replicates; tokens must still match the unpaged engine on
    the same mesh."""
    cfg = kv_cfg(4)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompts = shared_prefix_prompts(cfg)
    mesh = make_serving_mesh(4)
    want, _ = run_engine(cfg, params, prompts, paged=False, mesh=mesh)
    got, eng = run_engine(cfg, params, prompts, paged=True, mesh=mesh)
    assert got == want
    assert eng.capacity_report()["prefix_hit_tokens"] > 0


# ---------------------------------------------------------------------------
# Engine: capacity under a fixed budget
# ---------------------------------------------------------------------------

def test_paged_doubles_logical_slots_under_fixed_budget(tiny):
    """Same HBM budget, shared-prefix workload: the paged engine runs
    >= 2x the concurrent sequences the slot-contiguous engine can, with
    page-level accounting to show where the headroom came from."""
    cfg, params = tiny
    max_len, ps = 40, 8
    budget = 3 * cache_bytes_per_slot(cfg, max_len)
    unpaged = ServingEngine(cfg, params, config=EngineConfig(
        max_len=max_len, packed=False, prefill_chunk=8,
        hbm_cache_budget=budget))
    assert unpaged.max_batch == 3

    paged = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=8, max_len=max_len, packed=False, prefill_chunk=8,
        hbm_cache_budget=budget, paged=True, page_size=ps))
    rep = paged.capacity_report()
    assert rep["num_pages"] == 15 and rep["pages_per_slot"] == 5
    assert rep["guaranteed_slots"] == 3     # worst case: no better than slots

    rng = np.random.default_rng(9)
    prefix = rng.integers(0, cfg.vocab_size, 24).astype(np.int32)
    # warm the radix cache: one request covering exactly the shared prefix
    assert paged.submit(Request(uid=99, prompt=prefix, max_new_tokens=1))
    paged.run_to_completion()
    assert paged.capacity_report()["cached_prefix_pages"] == 3
    paged.peak_live_slots = 0

    reqs = [Request(uid=i,
                    prompt=np.concatenate([prefix, [i]]).astype(np.int32),
                    max_new_tokens=2)
            for i in range(8)]
    for r in reqs:
        assert paged.submit(r)
    got = {r.uid: tuple(r.output) for r in paged.run_to_completion()}

    rep = paged.capacity_report()
    assert rep["peak_live_slot_count"] >= 2 * unpaged.max_batch
    assert rep["prefix_hits"] >= 8 and rep["prefix_hit_tokens"] >= 8 * 24

    for r in reqs:
        assert unpaged.submit(Request(uid=r.uid, prompt=r.prompt,
                                      max_new_tokens=2))
    want = {r.uid: tuple(r.output) for r in unpaged.run_to_completion()}
    assert got == want


def test_paged_rejects_incompatible_configs(tiny):
    cfg, params = tiny
    with pytest.raises(ValueError, match="sliding-window"):
        ServingEngine(cfg.replace(sliding_window=8), params,
                      config=EngineConfig(max_len=32, packed=False,
                                          paged=True))
    with pytest.raises(ValueError, match="word-packing tail"):
        ServingEngine(cfg, params, config=EngineConfig(
            max_len=32, packed=False, paged=True, page_size=4))
    xcfg = configs.get_config("xlstm-1.3b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))
    xparams = lm.init_params(jax.random.PRNGKey(0), xcfg)
    with pytest.raises(ValueError, match="attention-free"):
        ServingEngine(xcfg, xparams, config=EngineConfig(
            max_len=32, packed=False, paged=True))


# ---------------------------------------------------------------------------
# Router: drain/restore carries the warm prefix cache
# ---------------------------------------------------------------------------

def test_paged_drain_restore_keeps_warm_prefix(tiny, tmp_path):
    """Drain -> checkpoint -> restore round-trips the page pools and the
    radix index: the restored replica still prefix-hits on the pre-drain
    prompt and serves token-identical output."""
    cfg, params = tiny
    econf = EngineConfig(max_batch=2, max_len=48, packed=False,
                         prefill_chunk=8, paged=True, page_size=16)
    prompts = shared_prefix_prompts(cfg)

    single = ServingEngine(cfg, params, config=econf)
    for i, p in enumerate(prompts):
        single.submit(Request(uid=i, prompt=p, max_new_tokens=4))
    want = {r.uid: tuple(r.output) for r in single.run_to_completion()}

    router = Router(cfg, params, config=econf, replicas=1,
                    checkpoint_dir=tmp_path)
    router.submit(prompts[0], max_new_tokens=4)
    router.run_to_completion()
    assert router.engines[0].capacity_report()["cached_prefix_pages"] == 2

    router.drain(0)
    router.restore(0)
    eng = router.engines[0]
    rep = eng.capacity_report()
    assert rep["cached_prefix_pages"] == 2   # the warm cache survived

    handles = [router.submit(p, max_new_tokens=4) for p in prompts]
    router.run_to_completion()
    assert {i: tuple(h.output) for i, h in enumerate(handles)} == want
    # prompts[0] resubmitted verbatim: its prefix must hit the restored
    # index without recomputation beyond the final row
    assert eng.capacity_report()["prefix_hit_tokens"] > 0

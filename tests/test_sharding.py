"""Sharding rules: path->spec mapping, divisibility guard, batch/cache specs.

These tests run against the production mesh SHAPE (via an AbstractMesh-like
check on specs) without needing 512 devices — the dry-run does the
device-level validation.
"""

import numpy as np
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.parallel import sharding


class FakeMesh:
    """Duck-typed mesh exposing .shape for rule evaluation."""

    def __init__(self, shape):
        self.shape = dict(shape)


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def spec_for(name, path, shape, mesh=MESH):
    cfg = configs.get_config(name)
    return sharding.param_pspec(path, np.zeros(shape), cfg, mesh)


class TestParamRules:
    def test_column_parallel_qkv(self):
        s = spec_for("stablelm-1.6b", "layers/0/attn/q/kernel", (2048, 2048))
        assert s == P(("data",), "model")

    def test_row_parallel_o(self):
        s = spec_for("stablelm-1.6b", "layers/0/attn/o/kernel", (2048, 2048))
        assert s == P("model", ("data",))

    def test_embed_untied_vs_tied(self):
        s = spec_for("stablelm-1.6b", "embed/table", (100608, 2048))
        assert s == P("model", ("data",))
        s = spec_for("granite-3-8b", "embed/table", (49408, 4096))
        assert s == P("model", None)

    def test_moe_expert_parallel_jamba(self):
        s = spec_for("jamba-1.5-large-398b", "layers/1/moe/up/kernel",
                     (16, 8192, 24576), MESH_MP)
        assert s == P("model", ("pod", "data"), None)

    def test_moe_tp_within_expert_mixtral(self):
        # 8 experts cannot divide model=16 -> TP on d_ff instead
        s = spec_for("mixtral-8x7b", "layers/0/moe/up/kernel",
                     (8, 4096, 14336))
        assert s == P(None, ("data",), "model")

    def test_divisibility_guard_drops_axis(self):
        # r_gates [nh=4, ...]: 4 does not divide 16 -> replicated
        s = spec_for("xlstm-1.3b", "layers/0/slstm/r_gates", (4, 512, 2048))
        assert all(a is None for a in s)

    def test_packed_weights_follow_kernel_rule(self):
        s = spec_for("stablelm-1.6b", "layers/0/attn/q/w_packed",
                     (1024, 2048))
        assert s == P(("data",), "model")
        s = spec_for("stablelm-1.6b", "layers/0/attn/q/col_sums", (2048,))
        assert s == P("model")

    def test_fsdp_over_pod(self):
        s = spec_for("jamba-1.5-large-398b", "layers/4/attn/q/kernel",
                     (8192, 8192), MESH_MP)
        assert s == P(("pod", "data"), "model")

    def test_scalars_replicated(self):
        s = spec_for("stablelm-1.6b", "layers/0/attn/q/w_step", ())
        assert s == P()


class TestBatchSpecs:
    def test_train_batch_sharded_over_dp(self):
        cfg = configs.get_config("stablelm-1.6b")
        assert sharding.batch_pspec(cfg, MESH, 256) == P(("data",))
        assert sharding.batch_pspec(cfg, MESH_MP, 256) == P(("pod", "data"))

    def test_batch_one_replicated(self):
        cfg = configs.get_config("mixtral-8x7b")
        assert sharding.batch_pspec(cfg, MESH, 1) == P(None)


class TestConstrainNoop:
    def test_constrain_is_noop_without_mesh(self):
        import jax.numpy as jnp
        x = jnp.ones((4, 4))
        y = sharding.constrain(x, "dp", None)
        assert y is x

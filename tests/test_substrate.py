"""Optimizer, schedules, data pipeline, gradient compression."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.optim import adamw, schedules
from repro.parallel import collectives


class TestAdamW:
    def _rosenbrock_opt(self, cfg, steps=300):
        params = {"x": jnp.asarray([-1.2, 1.0])}

        def loss(p):
            x, y = p["x"][0], p["x"][1]
            return (1 - x) ** 2 + 5.0 * (y - x * x) ** 2

        state = adamw.init(params, cfg)
        g = jax.jit(jax.grad(loss))
        for _ in range(steps):
            grads = g(params)
            updates, state = adamw.update(grads, state, params, 0.05, cfg)
            params = adamw.apply_updates(params, updates)
        return float(loss(params))

    def test_fp32_converges(self):
        cfg = adamw.AdamWConfig(weight_decay=0.0)
        assert self._rosenbrock_opt(cfg) < 0.2

    def test_8bit_moments_converge_close_to_fp32(self):
        ref = self._rosenbrock_opt(adamw.AdamWConfig(weight_decay=0.0))
        q = self._rosenbrock_opt(
            adamw.AdamWConfig(weight_decay=0.0, eightbit_moments=True))
        assert q < max(10 * ref, 0.5)

    def test_8bit_moment_memory_is_int8(self):
        cfg = adamw.AdamWConfig(eightbit_moments=True)
        params = {"w": jnp.zeros((1024,))}
        st = adamw.init(params, cfg)
        assert st["m"]["w"]["q"].dtype == jnp.int8

    def test_clip_by_global_norm(self):
        g = {"a": jnp.full((4,), 10.0)}
        clipped, norm = adamw.clip_by_global_norm(g, 1.0)
        assert float(norm) == 20.0
        assert np.isclose(float(adamw.global_norm(clipped)), 1.0, rtol=1e-4)


class TestSchedules:
    def test_cosine_warmup_peak_decay(self):
        lr0 = schedules.cosine_with_warmup(0, peak_lr=1.0, warmup_steps=10,
                                           total_steps=100)
        lrp = schedules.cosine_with_warmup(10, peak_lr=1.0, warmup_steps=10,
                                           total_steps=100)
        lre = schedules.cosine_with_warmup(100, peak_lr=1.0, warmup_steps=10,
                                           total_steps=100)
        assert float(lr0) == 0.0 and np.isclose(float(lrp), 1.0)
        assert float(lre) < 0.11

    def test_wsd_plateau_and_decay(self):
        mid = schedules.wsd(500, peak_lr=1.0, warmup_steps=10,
                            total_steps=1000)
        late = schedules.wsd(990, peak_lr=1.0, warmup_steps=10,
                             total_steps=1000)
        assert np.isclose(float(mid), 1.0)
        assert float(late) < 0.2


class TestDataPipeline:
    def test_deterministic_per_step(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7)
        a = SyntheticLMStream(cfg).batch_at(12)
        b = SyntheticLMStream(cfg).batch_at(12)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])

    def test_steps_differ(self):
        cfg = DataConfig(vocab_size=100, seq_len=16, global_batch=4)
        s = SyntheticLMStream(cfg)
        assert not np.array_equal(s.batch_at(0)["tokens"],
                                  s.batch_at(1)["tokens"])

    def test_labels_are_shifted_tokens(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2)
        b = SyntheticLMStream(cfg).batch_at(0)
        assert b["tokens"].shape == b["labels"].shape == (2, 8)

    def test_state_roundtrip(self):
        cfg = DataConfig(vocab_size=50, seq_len=8, global_batch=2, seed=3)
        s = SyntheticLMStream(cfg)
        st = s.state(41)
        s2 = SyntheticLMStream.from_state(cfg, st)
        np.testing.assert_array_equal(s.batch_at(41)["tokens"],
                                      s2.batch_at(41)["tokens"])


class TestGradCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(1000,)), jnp.float32)
        q, s = collectives.quantize_grad(g)
        deq = collectives.dequantize_grad(q, s, g.shape)
        err = np.abs(np.asarray(deq - g))
        block_max = np.abs(np.asarray(g)).max()
        assert err.max() <= block_max / 127.0 + 1e-6

    def test_error_feedback_reinjects_residual(self):
        g = {"w": jnp.asarray([1.0, 2.0, 3.0])}
        state = {"error_feedback": {"w": jnp.asarray([0.5, 0.0, 0.0])}}
        deq, new_state = collectives.compress_grads_with_feedback(g, state)
        # compressed(g + e) + new_e == g + e  (lossless bookkeeping)
        total = np.asarray(deq["w"]) + np.asarray(
            new_state["error_feedback"]["w"])
        np.testing.assert_allclose(total, [1.5, 2.0, 3.0], rtol=1e-6)

    def test_sgd_with_compression_converges(self):
        """Error feedback keeps compressed-SGD near the uncompressed path."""
        w = jnp.asarray([5.0, -3.0])
        state = {"error_feedback": {"w": jnp.zeros(2)}}
        target = jnp.asarray([1.0, 2.0])
        for _ in range(200):
            grads = {"w": 2 * (w - target)}
            deq, state = collectives.compress_grads_with_feedback(grads,
                                                                  state)
            w = w - 0.05 * deq["w"]
        np.testing.assert_allclose(np.asarray(w), np.asarray(target),
                                   atol=1e-2)

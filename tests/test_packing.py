"""Packing algebra: round-trips, overflow-region tightness, reference matmul."""

import jax.numpy as jnp
import numpy as np
import pytest
from conftest import hypothesis_or_stubs

from repro.core import packing
from repro.core.packing import PackSpec, k_tile_bound

given, settings, st = hypothesis_or_stubs()


def lattice(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2**bits, size=shape), jnp.int32)


class TestBounds:
    def test_paper_lp_region_matches_n_plus_m_le_7(self):
        # Paper §IV-A: 16-bit packed registers usable iff N+M <= 7.
        for w in range(1, 5):
            for a in range(1, 5):
                spec = PackSpec(w, a, jnp.int16.dtype)
                if w + a <= 7:
                    assert spec.feasible, (w, a)
                else:
                    assert not spec.feasible, (w, a)

    def test_known_k_tiles_s8(self):
        assert k_tile_bound(1, 1, 8) == 127
        assert k_tile_bound(2, 2, 8) == 14
        assert k_tile_bound(3, 3, 8) == 2
        assert k_tile_bound(4, 3, 8) == 1
        assert k_tile_bound(4, 4, 8) == 0

    def test_int8_ulp_region(self):
        # 8-bit lanes (S=4): the paper's ULP regime; only ~binary works.
        assert PackSpec(1, 1, jnp.int8.dtype).feasible
        assert PackSpec(1, 1, jnp.int8.dtype).k_tile == 7
        assert not PackSpec(2, 2, jnp.int8.dtype).feasible

    def test_p4_binary_extension(self):
        spec = PackSpec(1, 1, jnp.int16.dtype, n_pack=4)
        assert spec.feasible
        assert spec.k_tile >= 3


class TestPackUnpack:
    @given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 3),
           st.integers(2, 64))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_activations(self, w_bits, a_bits, rows, k):
        spec = PackSpec(max(w_bits, 1), a_bits, jnp.int16.dtype)
        rng = np.random.default_rng(k * 31 + rows)
        q = lattice(rng, (rows, k), a_bits)
        packed = packing.pack_activations(q, spec, axis=-1)
        assert packed.dtype == spec.lane_dtype
        back = packing.unpack(packed, spec, axis=-1)
        np.testing.assert_array_equal(np.asarray(back[:, :k]), np.asarray(q))

    @given(st.integers(1, 4), st.integers(2, 64), st.integers(1, 8))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_weights_reversed(self, w_bits, k, n):
        spec = PackSpec(w_bits, 1, jnp.int16.dtype)
        rng = np.random.default_rng(k * 7 + n)
        q = lattice(rng, (k, n), w_bits)
        packed = packing.pack_weights(q, spec, axis=0)
        back = packing.unpack(packed, spec, axis=0, reversed_fields=True)
        np.testing.assert_array_equal(np.asarray(back[:k]), np.asarray(q))

    def test_p4_roundtrip(self):
        spec = PackSpec(1, 1, jnp.int16.dtype, n_pack=4)
        rng = np.random.default_rng(0)
        q = lattice(rng, (5, 12), 1)
        packed = packing.pack_activations(q, spec, axis=-1)
        assert packed.shape == (5, 3)
        back = packing.unpack(packed, spec, axis=-1)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))


class TestSingleLaneAlgebra:
    def test_middle_band_is_dot(self):
        spec = PackSpec(3, 3, jnp.int16.dtype)
        a0, a1, w0, w1 = 5, 7, 3, 6
        a = jnp.asarray([[a0, a1]], jnp.int32)
        w = jnp.asarray([[w0], [w1]], jnp.int32)
        ap = packing.pack_activations(a, spec, -1)
        wp = packing.pack_weights(w, spec, 0)
        total = ap.astype(jnp.int32) * wp.astype(jnp.int32)[0]
        d = packing.extract_dot(total, spec)
        assert int(d[0, 0]) == a0 * w0 + a1 * w1


class TestTileBoundTightness:
    @pytest.mark.parametrize("w_bits,a_bits", [(1, 1), (2, 2), (3, 2), (3, 3)])
    def test_at_bound_exact(self, w_bits, a_bits):
        """Accumulating exactly k_tile worst-case lanes still extracts D."""
        spec = PackSpec(w_bits, a_bits, jnp.int16.dtype)
        kt = spec.k_tile
        k = 2 * kt
        # Worst case: all operands at max lattice value.
        q_a = jnp.full((1, k), spec.max_a, jnp.int32)
        q_w = jnp.full((k, 1), spec.max_w, jnp.int32)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        total = jnp.sum(ap.astype(jnp.int32)[0] * wp.astype(jnp.int32)[:, 0])
        d = packing.extract_dot(total, spec)
        assert int(d) == k * spec.max_a * spec.max_w

    @pytest.mark.parametrize("w_bits,a_bits", [(1, 1), (2, 2), (3, 3)])
    def test_beyond_bound_corrupts(self, w_bits, a_bits):
        """The k_tile bound is tight: one extra worst-case lane corrupts D
        (this is the overflow the paper's Fig. 5 region boundary encodes)."""
        spec = PackSpec(w_bits, a_bits, jnp.int16.dtype)
        k = 2 * (spec.k_tile + 1)
        q_a = jnp.full((1, k), spec.max_a, jnp.int32)
        q_w = jnp.full((k, 1), spec.max_w, jnp.int32)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        total = jnp.sum(ap.astype(jnp.int32)[0] * wp.astype(jnp.int32)[:, 0])
        d = packing.extract_dot(total, spec)
        assert int(d) != k * spec.max_a * spec.max_w


class TestPackWords:
    """Bit-dense int32 word packing (KV cache head-dim axis, dense weight
    store)."""

    @given(st.sampled_from([1, 2, 3, 4, 5, 8, 12, 16]), st.integers(1, 40),
           st.integers(0, 2))
    @settings(max_examples=30, deadline=None)
    def test_roundtrip_any_axis(self, bits, size, axis):
        rng = np.random.default_rng(bits * size + axis)
        shape = [3, 4, 5]
        shape[axis] = size
        q = lattice(rng, tuple(shape), bits)
        words = packing.pack_words(q, bits, axis=axis)
        per = 32 // bits
        assert words.shape[axis] == -(-size // per)
        assert words.dtype == jnp.int32
        back = packing.unpack_words(words, bits, size, axis=axis)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(q))

    def test_footprint_is_bit_exact_when_dividing(self):
        for bits in (2, 4, 8):
            per = 32 // bits
            q = jnp.zeros((2, per * 6), jnp.int32)
            words = packing.pack_words(q, bits, axis=-1)
            assert words.size * 32 == q.size * bits

    def test_nondividing_tail_is_zero_padded(self):
        q = jnp.full((1, 9), 15, jnp.int32)           # per=8 for 4 bits
        words = packing.pack_words(q, 4, axis=-1)
        assert words.shape == (1, 2)
        assert int(words[0, 1]) == 15                 # only field 0 occupied

    def test_nondividing_bits_roundtrip(self):
        """3-bit packs 10 values/word (top 2 bits unused) — the dense
        weight store supports every 1..8-bit lattice, not only dividers
        of 32."""
        rng = np.random.default_rng(3)
        q = lattice(rng, (21, 5), 3)
        words = packing.pack_words(q, 3, axis=0)
        assert words.shape[0] == -(-21 // 10)
        np.testing.assert_array_equal(
            np.asarray(packing.unpack_words(words, 3, 21, axis=0)),
            np.asarray(q))

    def test_invalid_bits_raise(self):
        q = jnp.zeros((2, 8), jnp.int32)
        with pytest.raises(ValueError):
            packing.pack_words(q, 0)
        with pytest.raises(ValueError):
            packing.unpack_words(q, 33, 8)


class TestLayoutFamily:
    """The autotuned lane-layout family (DESIGN.md §16): validity filtering,
    bit-exactness of every candidate, bound tightness, planner rejection."""

    def test_family_is_feasibility_filtered(self):
        for w in range(1, 5):
            for a in range(1, 5):
                fam = packing.layout_family(w, a)
                # int32/s16 keeps every (w, a) <= 4 pair feasible — W4A4
                # has no int16 layout but is NOT layout-starved.
                assert fam, (w, a)
                for spec in fam:
                    assert (spec.w_bits, spec.a_bits) == (w, a)
                    assert spec.feasible and spec.k_tile >= 1, str(spec)
                    lane, n, s = (np.dtype(spec.lane_dtype).name,
                                  spec.n_pack, spec.shift)
                    assert (lane, n, s) in packing.LAYOUT_FAMILY, str(spec)

    def test_base_spec_listed_first(self):
        base = PackSpec(2, 2, jnp.int16.dtype)
        assert packing.layout_family(2, 2, base)[0] == base

    def test_wide_fields_extend_the_region(self):
        # W4A4: infeasible on int16 (the paper's N+M<=7 wall) but feasible
        # on int32 s16 fields — the layout axis widens the Fig. 5 region.
        assert not PackSpec(4, 4, jnp.int16.dtype).feasible
        wide = PackSpec(4, 4, jnp.int32.dtype, shift=16)
        assert wide.feasible
        assert wide in packing.layout_family(4, 4)
        # and s16 fields multiply the accumulation run length: 3640 lanes
        # between extractions vs the int16 default's 14 at W2A2
        assert PackSpec(2, 2, jnp.int32.dtype, shift=16).k_tile \
            > 100 * PackSpec(2, 2, jnp.int16.dtype).k_tile

    @pytest.mark.parametrize("bits", [1, 2, 3, 4])
    @pytest.mark.parametrize("k", [8, 13])        # even K and odd-tail K
    def test_family_bit_exact_deterministic(self, bits, k):
        from repro.kernels import ref
        rng = np.random.default_rng(bits * 101 + k)
        q_a = lattice(rng, (3, k), bits)
        q_w = lattice(rng, (k, 5), bits)
        want = np.asarray(ref.matmul_i32_ref(q_a, q_w))
        for spec in packing.layout_family(bits, bits):
            got = packing.packed_matmul_reference(q_a, q_w, spec)
            np.testing.assert_array_equal(np.asarray(got), want,
                                          err_msg=str(spec))

    @given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 70),
           st.integers(1, 6))
    @settings(max_examples=40, deadline=None)
    def test_family_bit_exact_property(self, w_bits, a_bits, k, n):
        """Every feasible family layout reproduces the unpacked int32
        reference for every (w, a) <= 4 and every K tail parity."""
        from repro.kernels import ref
        rng = np.random.default_rng(w_bits * 1009 + a_bits * 97 + k * 5 + n)
        q_a = lattice(rng, (3, k), a_bits)
        q_w = lattice(rng, (k, n), w_bits)
        want = np.asarray(ref.matmul_i32_ref(q_a, q_w))
        for spec in packing.layout_family(w_bits, a_bits):
            got = packing.packed_matmul_reference(q_a, q_w, spec)
            np.testing.assert_array_equal(np.asarray(got), want,
                                          err_msg=str(spec))

    @pytest.mark.parametrize("w_bits,a_bits", [(1, 1), (2, 2), (3, 3),
                                               (4, 4), (2, 4)])
    def test_at_bound_exact_across_family(self, w_bits, a_bits):
        """Accumulating exactly k_tile worst-case lanes still extracts D —
        for int32/s16 this exercises the relaxed mod-2^32 wrap argument
        (bands above D wrap harmlessly; DESIGN.md §16)."""
        for spec in packing.layout_family(w_bits, a_bits):
            k = spec.n_pack * spec.k_tile
            q_a = jnp.full((1, k), spec.max_a, jnp.int32)
            q_w = jnp.full((k, 1), spec.max_w, jnp.int32)
            ap = packing.pack_activations(q_a, spec, -1)
            wp = packing.pack_weights(q_w, spec, 0)
            total = jnp.sum(ap.astype(jnp.int32)[0]
                            * wp.astype(jnp.int32)[:, 0])
            d = packing.extract_dot(total, spec)
            assert int(d) == k * spec.max_a * spec.max_w, str(spec)

    def test_beyond_bound_corrupts_wide_field(self):
        # Bound tightness holds for the new int32 s16 layout too: one extra
        # worst-case lane overflows D into the H band.
        spec = PackSpec(2, 2, jnp.int32.dtype, shift=16)
        k = 2 * (spec.k_tile + 1)
        q_a = jnp.full((1, k), spec.max_a, jnp.int32)
        q_w = jnp.full((k, 1), spec.max_w, jnp.int32)
        ap = packing.pack_activations(q_a, spec, -1)
        wp = packing.pack_weights(q_w, spec, 0)
        total = jnp.sum(ap.astype(jnp.int32)[0] * wp.astype(jnp.int32)[:, 0])
        assert int(packing.extract_dot(total, spec)) \
            != k * spec.max_a * spec.max_w

    def test_beyond_bound_rejected_by_planner(self):
        """A layout past the overflow bound never reaches a kernel: the
        planners reject it at plan time with the feasible alternatives."""
        from repro.kernels import plan as plan_lib
        spec = PackSpec(4, 4, jnp.int16.dtype)    # constructible, k_tile 0
        assert spec.k_tile == 0
        with pytest.raises(ValueError, match="overflow-free"):
            plan_lib.plan_packed_matmul(8, 16, 32, spec, backend="xla")
        with pytest.raises(ValueError, match="overflow-free"):
            plan_lib.plan_packed_conv2d((1, 8, 8, 8), (3, 3, 8, 8), spec,
                                        padding="SAME", backend="xla")

    def test_construction_errors_name_family(self):
        for build in (lambda: PackSpec(2, 2, jnp.float32.dtype),
                      lambda: PackSpec(2, 2, jnp.int16.dtype, n_pack=3),
                      lambda: PackSpec(2, 2, jnp.int16.dtype, shift=12)):
            with pytest.raises(ValueError) as e:
                build()
            assert "int16xP2s8" in str(e.value)   # the allowed family

    def test_from_config_rejects_infeasible_at_config_time(self):
        from repro.core.quant import QuantConfig
        bad = QuantConfig(enabled=True, w_bits=4, a_bits=4,
                          lane_dtype="int16")
        with pytest.raises(ValueError, match="Feasible layouts"):
            PackSpec.from_config(bad)
        ok = QuantConfig(enabled=True, w_bits=4, a_bits=4,
                         lane_dtype="int32", pack_shift=16)
        assert PackSpec.from_config(ok).k_tile >= 1

    def test_str_parse_roundtrip(self):
        for w, a in ((1, 1), (2, 2), (3, 3), (4, 4)):
            for spec in packing.layout_family(w, a):
                assert PackSpec.parse(str(spec)) == spec
        # pre-layout-sweep strings (no shift suffix) -> lane default
        assert PackSpec.parse("W2A2/int16xP2") == \
            PackSpec(2, 2, jnp.int16.dtype)
        with pytest.raises(ValueError, match="cannot parse"):
            PackSpec.parse("W2A2/int64xP2")


class TestPackedMatmulReference:
    @pytest.mark.parametrize("w_bits,a_bits,lane", [
        (1, 1, "int8"), (1, 1, "int16"), (2, 2, "int16"), (3, 2, "int16"),
        (3, 3, "int16"), (4, 3, "int16"), (2, 1, "int8"),
    ])
    def test_exact_vs_int_matmul(self, w_bits, a_bits, lane):
        from repro.kernels import ref
        spec = PackSpec(w_bits, a_bits, jnp.dtype(lane))
        rng = np.random.default_rng(42)
        q_a = lattice(rng, (9, 67), a_bits)
        q_w = lattice(rng, (67, 13), w_bits)
        got = packing.packed_matmul_reference(q_a, q_w, spec)
        want = ref.matmul_i32_ref(q_a, q_w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_p4_exact(self):
        from repro.kernels import ref
        spec = PackSpec(1, 1, jnp.int16.dtype, n_pack=4)
        rng = np.random.default_rng(3)
        q_a = lattice(rng, (4, 50), 1)
        q_w = lattice(rng, (50, 6), 1)
        got = packing.packed_matmul_reference(q_a, q_w, spec)
        want = ref.matmul_i32_ref(q_a, q_w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_infeasible_raises(self):
        spec = PackSpec(4, 4, jnp.int16.dtype)
        with pytest.raises(ValueError):
            packing.packed_matmul_reference(
                jnp.zeros((2, 4), jnp.int32), jnp.zeros((4, 2), jnp.int32),
                spec)

"""KernelPlan autotuner: cache load/fallback, planner consultation,
determinism, and the committed CPU tuning cache (DESIGN.md §14)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import PackSpec
from repro.kernels import autotune, ops, ref
from repro.kernels import plan as plan_lib

SPEC = PackSpec(2, 2, jnp.int16.dtype)


@pytest.fixture(autouse=True)
def _restore_active_cache():
    """Every test starts from the lazy default and leaves no cache behind."""
    autotune.reset_active_cache()
    yield
    autotune.reset_active_cache()


def _empty():
    return autotune.set_active_cache(autotune.TuningCache(device="cpu"))


class TestCacheFile:
    def test_save_load_roundtrip(self, tmp_path):
        c = autotune.TuningCache(device="cpu")
        key = autotune.matmul_key(8, 32, 64, SPEC, backend="xla")
        c.store(key, {"block_m": 32, "block_n": 64, "chunks": 2})
        path = c.save(str(tmp_path / "cache.json"))
        back = autotune.TuningCache.load(path)
        assert back is not None
        assert back.device == "cpu"
        assert back.lookup(key)["block_m"] == 32

    def test_missing_file_is_silent_none(self, tmp_path):
        assert autotune.TuningCache.load(str(tmp_path / "nope.json")) is None

    def test_corrupt_file_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("{not json")
        with pytest.warns(UserWarning, match="corrupt"):
            assert autotune.TuningCache.load(str(p)) is None
        # and the planners still work through load_cache on the bad file
        with pytest.warns(UserWarning, match="corrupt"):
            autotune.load_cache(str(p))
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_stale_schema_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "old.json"
        p.write_text(json.dumps({"schema": autotune.SCHEMA_VERSION + 1,
                                 "device": "cpu", "entries": {}}))
        with pytest.warns(UserWarning, match="schema"):
            assert autotune.TuningCache.load(str(p)) is None

    def test_entries_must_be_a_dict(self, tmp_path):
        p = tmp_path / "flat.json"
        p.write_text(json.dumps({"schema": autotune.SCHEMA_VERSION,
                                 "device": "cpu", "entries": [1, 2]}))
        with pytest.warns(UserWarning, match="entries"):
            assert autotune.TuningCache.load(str(p)) is None


class TestPlannerConsultation:
    def test_hit_returns_cache_backed_plan(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 32, "block_n": 64, "chunks": 2})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "tuned"
        assert (plan.block_m, plan.block_n, plan.chunks) == (32, 64, 2)
        # vmem estimate recomputed from the planner's own accounting
        assert plan.vmem_bytes == plan_lib.matmul_working_set(32, 64, 2,
                                                              SPEC)

    def test_miss_falls_back_to_heuristic(self):
        _empty()
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"
        assert plan.block_m == 128

    def test_use_tuning_cache_false_bypasses_hit(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 32, "block_n": 64, "chunks": 2})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla",
                                           use_tuning_cache=False)
        assert plan.source == "heuristic"

    def test_over_budget_entry_ignored(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 4096, "block_n": 4096, "chunks": 16})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_malformed_entry_ignored(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": "huge"})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_conv_hit_and_pinned_tiles_bypass(self):
        c = _empty()
        x_shape, w_shape = (1, 32, 32, 8), (3, 3, 8, 16)
        c.store(autotune.conv2d_key(x_shape, w_shape, SPEC,
                                    padding="VALID", backend="xla"),
                {"block_h": 4, "block_co": 16})
        plan = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                           padding="VALID", backend="xla")
        assert plan.source == "tuned"
        assert (plan.block_h, plan.block_co) == (4, 16)
        pinned = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                             padding="VALID", backend="xla",
                                             block_h=8)
        assert pinned.source == "heuristic" and pinned.block_h == 8

    def test_plan_selection_deterministic_given_fixed_cache(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 16, "block_n": 32, "chunks": 4})
        a = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        plan_lib.clear_plan_cache()
        b = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert a == b  # same frozen plan after a cold planner cache

    def test_attention_chunk_lookup(self):
        c = _empty()
        c.store(autotune.attention_key(2, 64, 64, 4, 2, 16, 0),
                {"q_chunk": 32})
        assert autotune.attention_chunk_for(2, 64, 64, 4, 2, 16, 0) == 32
        assert autotune.attention_chunk_for(1, 1, 1, 1, 1, 1, 0) == 512


class TestTuners:
    def test_tune_matmul_stores_winner_and_plan_adopts_it(self):
        cache = _empty()
        entry = autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla",
                                            repeats=1, max_candidates=3)
        for k in ("block_m", "block_n", "chunks", "wall_us",
                  "heuristic_us", "vmem_bytes", "candidates"):
            assert k in entry, k
        key = autotune.matmul_key(4, 8, 16, SPEC, backend="xla")
        assert cache.lookup(key) is entry
        plan = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert plan.source == "tuned"
        assert plan.block_m == entry["block_m"]
        # re-tune is a cache hit, not a re-measure
        again = autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert again is entry

    def test_tuned_plan_stays_bit_exact(self):
        _empty()
        rng = np.random.default_rng(0)
        q_a = jnp.asarray(rng.integers(0, 4, (5, 40)), jnp.int32)
        q_w = jnp.asarray(rng.integers(0, 4, (40, 16)), jnp.int32)
        from repro.core import packing
        ap = packing.pack_activations(q_a, SPEC, -1)
        wp = packing.pack_weights(q_w, SPEC, 0)
        autotune.tune_packed_matmul(5, ap.shape[-1], 16, SPEC,
                                    backend="pallas", repeats=1,
                                    max_candidates=3)
        plan = plan_lib.plan_packed_matmul(5, ap.shape[-1], 16, SPEC,
                                           backend="pallas")
        assert plan.source == "tuned"
        got = ops.packed_matmul(ap, wp, SPEC, plan=plan)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.matmul_i32_ref(q_a,
                                                                    q_w)))

    def test_tune_conv2d_stores_winner(self):
        cache = _empty()
        entry = autotune.tune_packed_conv2d(
            (1, 12, 12, 4), (3, 3, 4, 8), SPEC, padding="VALID",
            backend="xla", repeats=1, max_candidates=3)
        assert "block_h" in entry and "block_co" in entry
        key = autotune.conv2d_key((1, 12, 12, 4), (3, 3, 4, 8), SPEC,
                                  padding="VALID", backend="xla")
        assert cache.lookup(key) is entry

    def test_store_into_active_cache_invalidates_memoized_plans(self):
        _empty()
        before = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert before.source == "heuristic"
        autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla",
                                    repeats=1, max_candidates=2)
        after = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert after.source == "tuned"


class TestLayoutTuner:
    """Lane-layout sweep (DESIGN.md §16): the tuner stores a verified
    winner, resolution defaults to the config spec on miss/mismatch, and a
    pinned non-default winner flows pack -> plan -> dispatch bit-exactly."""

    def test_tune_matmul_layout_stores_verified_winner(self):
        cache = _empty()
        entry = autotune.tune_matmul_layout(4, 40, 16, SPEC, backend="xla",
                                            repeats=1, max_candidates=3)
        for field in ("spec", "wall_us", "base_spec", "base_us",
                      "candidates"):
            assert field in entry, field
        assert entry["base_spec"] == str(SPEC)
        assert entry["candidates"] >= 2      # family swept, not just base
        chosen = PackSpec.parse(entry["spec"])
        assert chosen.feasible
        key = autotune.matmul_layout_key(40, 16, 2, 2, backend="xla")
        assert cache.lookup(key) is entry
        # resolution returns the stored winner; re-tune is a cache hit
        assert autotune.matmul_layout_for(40, 16, SPEC,
                                          backend="xla") == chosen
        assert autotune.tune_matmul_layout(4, 40, 16, SPEC,
                                           backend="xla") is entry

    def test_tune_conv2d_layout_stores_verified_winner(self):
        cache = _empty()
        entry = autotune.tune_conv2d_layout(
            (1, 10, 10, 4), (3, 3, 4, 8), SPEC, padding="VALID",
            backend="xla", repeats=1, max_candidates=3)
        chosen = PackSpec.parse(entry["spec"])
        assert chosen.feasible
        key = autotune.conv2d_layout_key((1, 10, 10, 4), (3, 3, 4, 8), 2, 2,
                                         padding="VALID", backend="xla")
        assert cache.lookup(key) is entry
        assert autotune.conv2d_layout_for(
            (1, 10, 10, 4), (3, 3, 4, 8), SPEC, padding="VALID",
            backend="xla") == chosen

    def test_layout_for_defaults_to_base_on_miss(self):
        _empty()
        assert autotune.matmul_layout_for(40, 16, SPEC,
                                          backend="xla") == SPEC
        assert autotune.conv2d_layout_for(
            (1, 8, 8, 4), (3, 3, 4, 8), SPEC, padding="VALID",
            backend="xla") == SPEC

    def test_layout_for_ignores_unusable_entries(self):
        cache = _empty()
        key = autotune.matmul_layout_key(40, 16, 2, 2, backend="xla")
        for bad in ({"spec": "W4A4/int16xP2s8"},   # wrong bits + infeasible
                    {"spec": "garbage"},
                    {"wall_us": 3.0}):
            cache.store(key, bad)
            assert autotune.matmul_layout_for(40, 16, SPEC,
                                              backend="xla") == SPEC

    def test_layout_key_excludes_rows(self):
        # Weights pack once and serve every batch size: the layout choice
        # may not depend on m.
        k1 = autotune.matmul_layout_key(40, 16, 2, 2, backend="xla")
        assert "m=" not in k1 and "k=40" in k1 and "n=16" in k1

    def test_chosen_layout_flows_pack_plan_dispatch(self):
        """Pin a non-default winner; pack_dense_params packs under it,
        build_layer_plans plans under it, dense_apply dispatches under it —
        bit-exact against the float reference path's quantized result."""
        from repro.core.quant import QuantConfig
        from repro.models import common
        from repro.serve import prepare

        cache = _empty()
        qcfg = QuantConfig(enabled=True, w_bits=2, a_bits=2)
        k, n = 32, 16
        wide = PackSpec(2, 2, jnp.int32.dtype, shift=16)
        backend = plan_lib.resolve_backend("auto")
        cache.store(autotune.matmul_layout_key(k, n, 2, 2, backend=backend),
                    {"spec": str(wide)})

        rng = np.random.default_rng(7)
        p = {"kernel": jnp.asarray(rng.normal(size=(k, n)) * 0.1,
                                   jnp.float32)}
        packed = common.pack_dense_params(p, qcfg)
        assert packed["w_packed"].dtype == wide.lane_dtype
        assert packed["w_packed"].shape[0] == -(-k // wide.n_pack)

        class Cfg:
            quant = qcfg
        plans = prepare.build_layer_plans({"mlp": packed}, Cfg(),
                                          batch_rows=4)
        assert plans["mlp"].spec == wide

        x = jnp.asarray(rng.normal(size=(4, k)), jnp.float32)
        y = common.dense_apply(packed, x, qcfg=qcfg, quant_mode="packed",
                               compute_dtype=jnp.float32)
        # same quantized result as packing under the config default
        base_packed = common.pack_dense_params(p, qcfg, spec=SPEC)
        y_base = common.dense_apply(base_packed, x, qcfg=qcfg,
                                    quant_mode="packed",
                                    compute_dtype=jnp.float32)
        assert base_packed["w_packed"].dtype == SPEC.lane_dtype
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_base),
                                   rtol=1e-5, atol=1e-5)

    def test_stale_layout_cache_falls_back_on_packed_evidence(self):
        """Bytes packed under the default, cache later says int32/s16: the
        packed leaf contradicts the resolved layout, so dispatch falls back
        to the layout the bytes actually use instead of misreading them."""
        from repro.core.quant import QuantConfig
        from repro.models import common

        cache = _empty()
        qcfg = QuantConfig(enabled=True, w_bits=2, a_bits=2)
        k, n = 32, 16
        rng = np.random.default_rng(3)
        p = {"kernel": jnp.asarray(rng.normal(size=(k, n)) * 0.1,
                                   jnp.float32)}
        packed = common.pack_dense_params(p, qcfg)   # default layout
        backend = plan_lib.resolve_backend("auto")
        cache.store(autotune.matmul_layout_key(k, n, 2, 2, backend=backend),
                    {"spec": str(PackSpec(2, 2, jnp.int32.dtype, shift=16))})
        spec = common.dense_layer_spec(k, n, qcfg,
                                       w_packed=packed["w_packed"])
        assert spec == SPEC
        x = jnp.asarray(rng.normal(size=(2, k)), jnp.float32)
        y = common.dense_apply(packed, x, qcfg=qcfg, quant_mode="packed",
                               compute_dtype=jnp.float32)
        assert np.isfinite(np.asarray(y)).all()


class TestMeasure:
    def test_median_of_repeats_scales_batch_to_min_time(self):
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        us = autotune.measure_us(fn, repeats=3, min_time_s=0.001, iters=1)
        assert us > 0
        # warmup + calibration doubling + repeat batches all landed
        assert len(calls) >= 4

    def test_zero_min_time_keeps_fixed_iters(self):
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        autotune.measure_us(fn, repeats=2, min_time_s=0.0, iters=3,
                            warmup=1)
        assert len(calls) == 1 + 3 + 3


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="committed tuning cache is CPU-scoped")
class TestCommittedCache:
    """Acceptance: with the committed CPU cache, the planners return
    cache-backed plans for the benchmarked signatures."""

    def test_committed_cache_loads(self):
        path = autotune.default_cache_path("cpu")
        cache = autotune.TuningCache.load(path)
        assert cache is not None, path
        assert cache.device == "cpu"
        assert cache.entries

    def test_planners_return_cache_backed_plans(self):
        mm = plan_lib.plan_packed_matmul(8, 128, 256, SPEC,
                                         backend="pallas")
        assert mm.source == "tuned"
        conv = plan_lib.plan_packed_conv2d(
            (1, 64, 64, 16), (7, 7, 16, 32), SPEC, padding="VALID",
            backend="pallas")
        assert conv.source == "tuned"

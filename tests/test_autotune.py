"""KernelPlan autotuner: cache load/fallback, planner consultation,
determinism, and the committed CPU tuning cache (DESIGN.md §14)."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.packing import PackSpec
from repro.kernels import autotune, ops, ref
from repro.kernels import plan as plan_lib

SPEC = PackSpec(2, 2, jnp.int16.dtype)


@pytest.fixture(autouse=True)
def _restore_active_cache():
    """Every test starts from the lazy default and leaves no cache behind."""
    autotune.reset_active_cache()
    yield
    autotune.reset_active_cache()


def _empty():
    return autotune.set_active_cache(autotune.TuningCache(device="cpu"))


class TestCacheFile:
    def test_save_load_roundtrip(self, tmp_path):
        c = autotune.TuningCache(device="cpu")
        key = autotune.matmul_key(8, 32, 64, SPEC, backend="xla")
        c.store(key, {"block_m": 32, "block_n": 64, "chunks": 2})
        path = c.save(str(tmp_path / "cache.json"))
        back = autotune.TuningCache.load(path)
        assert back is not None
        assert back.device == "cpu"
        assert back.lookup(key)["block_m"] == 32

    def test_missing_file_is_silent_none(self, tmp_path):
        assert autotune.TuningCache.load(str(tmp_path / "nope.json")) is None

    def test_corrupt_file_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "bad.json"
        p.write_text("{not json")
        with pytest.warns(UserWarning, match="corrupt"):
            assert autotune.TuningCache.load(str(p)) is None
        # and the planners still work through load_cache on the bad file
        with pytest.warns(UserWarning, match="corrupt"):
            autotune.load_cache(str(p))
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_stale_schema_warns_and_falls_back(self, tmp_path):
        p = tmp_path / "old.json"
        p.write_text(json.dumps({"schema": autotune.SCHEMA_VERSION + 1,
                                 "device": "cpu", "entries": {}}))
        with pytest.warns(UserWarning, match="schema"):
            assert autotune.TuningCache.load(str(p)) is None

    def test_entries_must_be_a_dict(self, tmp_path):
        p = tmp_path / "flat.json"
        p.write_text(json.dumps({"schema": autotune.SCHEMA_VERSION,
                                 "device": "cpu", "entries": [1, 2]}))
        with pytest.warns(UserWarning, match="entries"):
            assert autotune.TuningCache.load(str(p)) is None


class TestPlannerConsultation:
    def test_hit_returns_cache_backed_plan(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 32, "block_n": 64, "chunks": 2})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "tuned"
        assert (plan.block_m, plan.block_n, plan.chunks) == (32, 64, 2)
        # vmem estimate recomputed from the planner's own accounting
        assert plan.vmem_bytes == plan_lib.matmul_working_set(32, 64, 2,
                                                              SPEC)

    def test_miss_falls_back_to_heuristic(self):
        _empty()
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"
        assert plan.block_m == 128

    def test_use_tuning_cache_false_bypasses_hit(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 32, "block_n": 64, "chunks": 2})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla",
                                           use_tuning_cache=False)
        assert plan.source == "heuristic"

    def test_over_budget_entry_ignored(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 4096, "block_n": 4096, "chunks": 16})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_malformed_entry_ignored(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": "huge"})
        plan = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert plan.source == "heuristic"

    def test_conv_hit_and_pinned_tiles_bypass(self):
        c = _empty()
        x_shape, w_shape = (1, 32, 32, 8), (3, 3, 8, 16)
        c.store(autotune.conv2d_key(x_shape, w_shape, SPEC,
                                    padding="VALID", backend="xla"),
                {"block_h": 4, "block_co": 16})
        plan = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                           padding="VALID", backend="xla")
        assert plan.source == "tuned"
        assert (plan.block_h, plan.block_co) == (4, 16)
        pinned = plan_lib.plan_packed_conv2d(x_shape, w_shape, SPEC,
                                             padding="VALID", backend="xla",
                                             block_h=8)
        assert pinned.source == "heuristic" and pinned.block_h == 8

    def test_plan_selection_deterministic_given_fixed_cache(self):
        c = _empty()
        c.store(autotune.matmul_key(8, 32, 64, SPEC, backend="xla"),
                {"block_m": 16, "block_n": 32, "chunks": 4})
        a = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        plan_lib.clear_plan_cache()
        b = plan_lib.plan_packed_matmul(8, 32, 64, SPEC, backend="xla")
        assert a == b  # same frozen plan after a cold planner cache

    def test_attention_chunk_lookup(self):
        c = _empty()
        c.store(autotune.attention_key(2, 64, 64, 4, 2, 16, 0),
                {"q_chunk": 32})
        assert autotune.attention_chunk_for(2, 64, 64, 4, 2, 16, 0) == 32
        assert autotune.attention_chunk_for(1, 1, 1, 1, 1, 1, 0) == 512


class TestTuners:
    def test_tune_matmul_stores_winner_and_plan_adopts_it(self):
        cache = _empty()
        entry = autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla",
                                            repeats=1, max_candidates=3)
        for k in ("block_m", "block_n", "chunks", "wall_us",
                  "heuristic_us", "vmem_bytes", "candidates"):
            assert k in entry, k
        key = autotune.matmul_key(4, 8, 16, SPEC, backend="xla")
        assert cache.lookup(key) is entry
        plan = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert plan.source == "tuned"
        assert plan.block_m == entry["block_m"]
        # re-tune is a cache hit, not a re-measure
        again = autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert again is entry

    def test_tuned_plan_stays_bit_exact(self):
        _empty()
        rng = np.random.default_rng(0)
        q_a = jnp.asarray(rng.integers(0, 4, (5, 40)), jnp.int32)
        q_w = jnp.asarray(rng.integers(0, 4, (40, 16)), jnp.int32)
        from repro.core import packing
        ap = packing.pack_activations(q_a, SPEC, -1)
        wp = packing.pack_weights(q_w, SPEC, 0)
        autotune.tune_packed_matmul(5, ap.shape[-1], 16, SPEC,
                                    backend="pallas", repeats=1,
                                    max_candidates=3)
        plan = plan_lib.plan_packed_matmul(5, ap.shape[-1], 16, SPEC,
                                           backend="pallas")
        assert plan.source == "tuned"
        got = ops.packed_matmul(ap, wp, SPEC, plan=plan)
        np.testing.assert_array_equal(np.asarray(got),
                                      np.asarray(ref.matmul_i32_ref(q_a,
                                                                    q_w)))

    def test_tune_conv2d_stores_winner(self):
        cache = _empty()
        entry = autotune.tune_packed_conv2d(
            (1, 12, 12, 4), (3, 3, 4, 8), SPEC, padding="VALID",
            backend="xla", repeats=1, max_candidates=3)
        assert "block_h" in entry and "block_co" in entry
        key = autotune.conv2d_key((1, 12, 12, 4), (3, 3, 4, 8), SPEC,
                                  padding="VALID", backend="xla")
        assert cache.lookup(key) is entry

    def test_store_into_active_cache_invalidates_memoized_plans(self):
        _empty()
        before = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert before.source == "heuristic"
        autotune.tune_packed_matmul(4, 8, 16, SPEC, backend="xla",
                                    repeats=1, max_candidates=2)
        after = plan_lib.plan_packed_matmul(4, 8, 16, SPEC, backend="xla")
        assert after.source == "tuned"


class TestMeasure:
    def test_median_of_repeats_scales_batch_to_min_time(self):
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        us = autotune.measure_us(fn, repeats=3, min_time_s=0.001, iters=1)
        assert us > 0
        # warmup + calibration doubling + repeat batches all landed
        assert len(calls) >= 4

    def test_zero_min_time_keeps_fixed_iters(self):
        calls = []

        def fn():
            calls.append(1)
            return jnp.zeros(())

        autotune.measure_us(fn, repeats=2, min_time_s=0.0, iters=3,
                            warmup=1)
        assert len(calls) == 1 + 3 + 3


@pytest.mark.skipif(jax.default_backend() != "cpu",
                    reason="committed tuning cache is CPU-scoped")
class TestCommittedCache:
    """Acceptance: with the committed CPU cache, the planners return
    cache-backed plans for the benchmarked signatures."""

    def test_committed_cache_loads(self):
        path = autotune.default_cache_path("cpu")
        cache = autotune.TuningCache.load(path)
        assert cache is not None, path
        assert cache.device == "cpu"
        assert cache.entries

    def test_planners_return_cache_backed_plans(self):
        mm = plan_lib.plan_packed_matmul(8, 128, 256, SPEC,
                                         backend="pallas")
        assert mm.source == "tuned"
        conv = plan_lib.plan_packed_conv2d(
            (1, 64, 64, 16), (7, 7, 16, 32), SPEC, padding="VALID",
            backend="pallas")
        assert conv.source == "tuned"

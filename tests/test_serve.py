"""Serving correctness: prefill/decode consistency vs full forward, SWA ring
buffer, packed-vs-qat logits closeness, engine continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch import steps as steps_lib
from repro.models import lm


def float_cfg(name, **kw):
    cfg = configs.get_config(name, reduced=True)
    # capacity_factor high enough to be dropless: teacher-forced and
    # token-by-token paths then agree exactly (drops are a train-time
    # throughput trade-off, not a serving semantic)
    return cfg.replace(param_dtype="float32", compute_dtype="float32",
                       quant=QuantConfig(enabled=False),
                       capacity_factor=8.0, **kw)


def _decode_all(cfg, params, tokens, max_len):
    """Feed tokens one-by-one through the decode step; return last logits."""
    decode = steps_lib.make_decode_step(cfg)
    b, s = tokens.shape
    caches = lm.init_caches(cfg, b, max_len, dtype=jnp.float32)
    logits = None
    for t in range(s):
        batch = {"tokens": tokens[:, t:t + 1]}
        if cfg.mrope:
            pos = jnp.full((3, b, 1), t, jnp.int32)
            batch["positions3"] = pos
        logits, caches = decode(params, caches, batch, jnp.int32(t))
    return logits


@pytest.mark.parametrize("name", ["stablelm-1.6b", "granite-3-8b",
                                  "mixtral-8x7b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_full_forward(name):
    """Token-by-token decode == teacher-forced forward on the last position.
    Covers KV cache (GQA), SWA ring buffer, mamba/mLSTM/sLSTM state."""
    cfg = float_cfg(name)
    rng = np.random.default_rng(0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    s = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32)

    full_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec_logits = _decode_all(cfg, params, tokens, max_len=s + 2)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_bounded_and_correct():
    """With window w, decode logits match full forward even when the ring
    cache is much smaller than the sequence."""
    cfg = float_cfg("mixtral-8x7b").replace(sliding_window=6)
    rng = np.random.default_rng(1)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    s = 17
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)
    full_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec_logits = _decode_all(cfg, params, tokens, max_len=64)
    caches = lm.init_caches(cfg, 1, 64, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].shape[1] == 6  # ring bounded by window
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_continues_correctly():
    cfg = float_cfg("stablelm-1.6b")
    rng = np.random.default_rng(2)
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    prefill = steps_lib.make_prefill_step(cfg, max_len=16)
    decode = steps_lib.make_decode_step(cfg)
    last, caches = prefill(params, {"tokens": tokens[:, :8]})
    for t in (8, 9):
        last, caches = decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                              jnp.int32(t))
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_encdec_decode_uses_cached_cross_kv():
    cfg = float_cfg("seamless-m4t-medium")
    rng = np.random.default_rng(3)
    params = lm.init_params(jax.random.PRNGKey(3), cfg)
    enc = jnp.asarray(rng.normal(size=(2, 6, cfg.frontend_dim)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 5)), jnp.int32)
    # teacher-forced full forward
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens,
                                          "enc_embeds": enc})
    # prefill-style: encode once, decode token by token with cached cross-KV
    enc_out = lm.encode(params, cfg, enc)
    caches = lm.init_caches(cfg, 2, 8, dtype=jnp.float32)
    logits = None
    for t in range(5):
        logits, _, caches = lm.forward(
            params, cfg, {"tokens": tokens[:, t:t + 1],
                          "positions": jnp.full((2, 1), t, jnp.int32)},
            caches=caches, cache_index=jnp.int32(t),
            enc_out=enc_out if t == 0 else None)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_packed_decode_close_to_qat_forward():
    """The deployed integer path approximates the QAT fake-quant numerics
    (exact on the shared lattice up to activation-quant differences)."""
    from repro.serve.prepare import prepare_serving_params
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=3, a_bits=3))
    rng = np.random.default_rng(4)
    params = lm.init_params(jax.random.PRNGKey(4), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    qat_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens},
                                  quant_mode="qat")
    sp = prepare_serving_params(params, cfg)
    dec = _decode_all(cfg, sp, tokens, max_len=8)
    ref = np.asarray(qat_logits[:, -1, :cfg.vocab_size])
    got = np.asarray(dec[:, :cfg.vocab_size])
    # integer path vs fake-quant path: same weights lattice, activations
    # quantized at different points -> close but not identical
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert corr > 0.98, corr


def test_serving_engine_continuous_batching():
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(cfg, params, max_batch=2, max_len=32, packed=False)
    rng = np.random.default_rng(6)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4).astype(
                        np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(r.output) == 3 for r in done)


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (beyond-paper §Perf optimization) stays close to the
    full-precision decode path."""
    cfg = float_cfg("granite-3-8b")
    cfg = cfg.replace(quant=QuantConfig(enabled=False, kv_bits=8))
    rng = np.random.default_rng(7)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].dtype == jnp.int8
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec = _decode_all(cfg, params, tokens, max_len=16)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=0.05, atol=0.05)

"""Serving correctness: prefill/decode consistency vs full forward, SWA ring
buffer, packed-vs-qat logits closeness, and the continuous-batching engine
(chunked prefill, ragged per-slot positions, sampling, backpressure —
DESIGN.md §12)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.quant import QuantConfig
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.serve.config import EngineConfig


def float_cfg(name, **kw):
    cfg = configs.get_config(name, reduced=True)
    # capacity_factor high enough to be dropless: teacher-forced and
    # token-by-token paths then agree exactly (drops are a train-time
    # throughput trade-off, not a serving semantic)
    return cfg.replace(param_dtype="float32", compute_dtype="float32",
                       quant=QuantConfig(enabled=False),
                       capacity_factor=8.0, **kw)


def _decode_all(cfg, params, tokens, max_len):
    """Feed tokens one-by-one through the decode step; return last logits."""
    decode = steps_lib.make_decode_step(cfg)
    b, s = tokens.shape
    caches = lm.init_caches(cfg, b, max_len, dtype=jnp.float32)
    logits = None
    for t in range(s):
        batch = {"tokens": tokens[:, t:t + 1]}
        if cfg.mrope:
            pos = jnp.full((3, b, 1), t, jnp.int32)
            batch["positions3"] = pos
        logits, caches = decode(params, caches, batch, jnp.int32(t))
    return logits


@pytest.mark.parametrize("name", ["stablelm-1.6b", "granite-3-8b",
                                  "mixtral-8x7b", "xlstm-1.3b",
                                  "jamba-1.5-large-398b"])
def test_decode_matches_full_forward(name):
    """Token-by-token decode == teacher-forced forward on the last position.
    Covers KV cache (GQA), SWA ring buffer, mamba/mLSTM/sLSTM state."""
    cfg = float_cfg(name)
    rng = np.random.default_rng(0)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    s = 12
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, s)), jnp.int32)

    full_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec_logits = _decode_all(cfg, params, tokens, max_len=s + 2)
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_swa_ring_buffer_bounded_and_correct():
    """With window w, decode logits match full forward even when the ring
    cache is much smaller than the sequence."""
    cfg = float_cfg("mixtral-8x7b").replace(sliding_window=6)
    rng = np.random.default_rng(1)
    params = lm.init_params(jax.random.PRNGKey(1), cfg)
    s = 17
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, s)), jnp.int32)
    full_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec_logits = _decode_all(cfg, params, tokens, max_len=64)
    caches = lm.init_caches(cfg, 1, 64, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].shape[1] == 6  # ring bounded by window
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_prefill_then_decode_continues_correctly():
    cfg = float_cfg("stablelm-1.6b")
    rng = np.random.default_rng(2)
    params = lm.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 10)), jnp.int32)
    prefill = steps_lib.make_prefill_step(cfg, max_len=16)
    decode = steps_lib.make_decode_step(cfg)
    last, caches = prefill(params, {"tokens": tokens[:, :8]})
    for t in (8, 9):
        last, caches = decode(params, caches, {"tokens": tokens[:, t:t + 1]},
                              jnp.int32(t))
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(last), np.asarray(full[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_encdec_decode_uses_cached_cross_kv():
    cfg = float_cfg("seamless-m4t-medium")
    rng = np.random.default_rng(3)
    params = lm.init_params(jax.random.PRNGKey(3), cfg)
    enc = jnp.asarray(rng.normal(size=(2, 6, cfg.frontend_dim)), jnp.float32)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 5)), jnp.int32)
    # teacher-forced full forward
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens,
                                          "enc_embeds": enc})
    # prefill-style: encode once, decode token by token with cached cross-KV
    enc_out = lm.encode(params, cfg, enc)
    caches = lm.init_caches(cfg, 2, 8, dtype=jnp.float32)
    logits = None
    for t in range(5):
        logits, _, caches = lm.forward(
            params, cfg, {"tokens": tokens[:, t:t + 1],
                          "positions": jnp.full((2, 1), t, jnp.int32)},
            caches=caches, cache_index=jnp.int32(t),
            enc_out=enc_out if t == 0 else None)
    np.testing.assert_allclose(np.asarray(logits[:, -1]),
                               np.asarray(full[:, -1]), rtol=2e-3, atol=2e-3)


def test_packed_decode_close_to_qat_forward():
    """The deployed integer path approximates the QAT fake-quant numerics
    (exact on the shared lattice up to activation-quant differences)."""
    from repro.serve.prepare import prepare_serving_params
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=3, a_bits=3))
    rng = np.random.default_rng(4)
    params = lm.init_params(jax.random.PRNGKey(4), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (1, 6)), jnp.int32)
    qat_logits, _, _ = lm.forward(params, cfg, {"tokens": tokens},
                                  quant_mode="qat")
    sp = prepare_serving_params(params, cfg)
    dec = _decode_all(cfg, sp, tokens, max_len=8)
    ref = np.asarray(qat_logits[:, -1, :cfg.vocab_size])
    got = np.asarray(dec[:, :cfg.vocab_size])
    # integer path vs fake-quant path: same weights lattice, activations
    # quantized at different points -> close but not identical
    corr = np.corrcoef(ref.ravel(), got.ravel())[0, 1]
    assert corr > 0.98, corr


def test_serving_engine_continuous_batching():
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=32, packed=False))
    rng = np.random.default_rng(6)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 4).astype(
                        np.int32),
                    max_new_tokens=3) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(len(r.output) == 3 for r in done)


# ---------------------------------------------------------------------------
# Ragged continuous batching (per-slot positions, DESIGN.md §12)
# ---------------------------------------------------------------------------

def _assert_staggered_decode_matches_single(cfg, seed, lens=(9, 5),
                                            started=(0, 4), max_len=16):
    """Drive two slots at staggered offsets through the vector-cache_index
    decode step and assert each matches its single-sequence reference.

    Uses the eager step: exact-logits asserts through large jitted
    programs hit a transient XLA:CPU execution race under CI memory
    pressure (same executable + same inputs can differ across runs);
    eager is deterministic, traces the identical ragged-position code,
    and the jitted path is covered token-for-token by the engine
    staggered-admission tests."""
    rng = np.random.default_rng(seed)
    params = lm.init_params(jax.random.PRNGKey(seed), cfg)
    toks = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]
    refs = [np.asarray(_decode_all(cfg, params, jnp.asarray(t[None]),
                                   max_len))[0]
            for t in toks]

    decode = steps_lib.make_decode_step(cfg)
    caches = lm.init_caches(cfg, 2, max_len, dtype=jnp.float32)
    pos = np.zeros(2, np.int32)
    last = {}
    for tick in range(started[1] + lens[1]):
        tokens = np.zeros((2, 1), np.int32)
        valid = np.zeros(2, np.int32)
        for s in range(2):
            tl = tick - started[s]
            if 0 <= tl < lens[s]:
                tokens[s, 0] = toks[s][tl]
                valid[s] = 1
        # jnp.array (copy) — pos is mutated in place below, and a
        # zero-copy asarray would alias the buffer the async step reads
        logits, caches = decode(params, caches,
                                {"tokens": jnp.array(tokens)},
                                jnp.array(pos), jnp.array(valid))
        for s in range(2):
            if valid[s]:
                pos[s] += 1
                if tick - started[s] == lens[s] - 1:
                    last[s] = np.asarray(logits[s])
    for s in range(2):
        np.testing.assert_allclose(last[s], refs[s], rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("name", ["stablelm-1.6b", "jamba-1.5-large-398b"])
def test_ragged_decode_matches_single_sequence(name):
    """Vector cache_index decode: two slots advanced at staggered offsets
    produce the same logits as each sequence decoded alone (regression for
    the old lockstep max(slot_pos) position hack)."""
    _assert_staggered_decode_matches_single(float_cfg(name), seed=8)


def test_ragged_decode_sliding_window_matches_single():
    """Same, over a sliding-window ring cache (exercises the batched
    ring-position masking and per-slot ring writes)."""
    cfg = float_cfg("mixtral-8x7b").replace(sliding_window=6)
    assert lm.init_caches(cfg, 2, 16, dtype=jnp.float32)[0]["attn"][
        "k"].shape[1] == 6                    # ring bounded by window
    _assert_staggered_decode_matches_single(cfg, seed=14)


def test_engine_sliding_window_forces_token_prefill():
    """Ring-cache archs clamp prefill_chunk to 1 (chunked windows would
    overwrite slots still visible to earlier in-window queries) and still
    match the single-request reference token-for-token."""
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("mixtral-8x7b").replace(sliding_window=8)
    params = lm.init_params(jax.random.PRNGKey(15), cfg)
    rng = np.random.default_rng(15)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 3, 7)]

    def run(max_batch):
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=max_batch, max_len=32, packed=False,
            prefill_chunk=16))
        assert eng.prefill_chunk == 1
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        return {r.uid: tuple(r.output) for r in eng.run_to_completion()}

    assert run(2) == run(1)


@pytest.mark.parametrize("name", ["stablelm-1.6b", "xlstm-1.3b"])
def test_chunked_prefill_step_matches_decode(name):
    """make_prefill_chunk_step over ragged [B, chunk] windows reproduces
    token-by-token decode logits (attention ring writes + recurrent-state
    gating for pad tokens)."""
    cfg = float_cfg(name)
    rng = np.random.default_rng(9)
    params = lm.init_params(jax.random.PRNGKey(9), cfg)
    lens = np.asarray((11, 6))
    toks = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
            for n in lens]
    refs = [np.asarray(_decode_all(cfg, params, jnp.asarray(t[None]), 16))[0]
            for t in toks]

    pstep = steps_lib.make_prefill_chunk_step(cfg)  # eager: see ragged test
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    pos = np.zeros(2, np.int32)
    fed = np.zeros(2, np.int32)
    chunk, last = 4, {}
    while (fed < lens).any():
        tokens = np.zeros((2, chunk), np.int32)
        valid = np.zeros(2, np.int32)
        for s in range(2):
            t = min(chunk, int(lens[s] - fed[s]))
            if t > 0:
                tokens[s, :t] = toks[s][fed[s]:fed[s] + t]
                valid[s] = t
        logits, caches = pstep(params, caches,
                               {"tokens": jnp.array(tokens)},
                               jnp.array(pos), jnp.array(valid))
        for s in range(2):
            if valid[s]:
                fed[s] += valid[s]
                pos[s] += valid[s]
                if fed[s] == lens[s]:
                    last[s] = np.asarray(logits[s])
    for s in range(2):
        np.testing.assert_allclose(last[s], refs[s], rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("chunk", [1, 4])
def test_engine_staggered_admission_matches_single_request(chunk):
    """The ragged-position regression test: four prompts of different
    lengths through a 3-slot engine (admissions land at staggered, per-slot
    positions; one request is admitted mid-flight into a freed slot) must
    generate token-for-token what a single-request engine generates."""
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(10)
    prompts = [rng.integers(0, cfg.vocab_size, n).astype(np.int32)
               for n in (7, 3, 11, 5)]

    def run(max_batch):
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=max_batch, max_len=32, packed=False,
            prefill_chunk=chunk))
        for i, p in enumerate(prompts):
            assert eng.submit(Request(uid=i, prompt=p, max_new_tokens=6))
        return {r.uid: tuple(r.output) for r in eng.run_to_completion()}

    staggered = run(3)
    sequential = run(1)
    assert staggered == sequential


def test_run_to_completion_collects_same_step_finishers():
    """A request with max_new_tokens=1 whose whole prompt fits one prefill
    chunk is admitted, prefilled, and retired inside a single step(); the
    old before-admission snapshot dropped it."""
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(11)
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=32, packed=False, prefill_chunk=8))
    for i in range(3):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, 3).astype(
                np.int32),
            max_new_tokens=1))
    done = eng.run_to_completion()
    assert len(done) == 3
    assert all(r.done and len(r.output) == 1 for r in done)


def test_engine_per_slot_sampling():
    """Greedy and temperature/top-k requests coexist in one batch; sampled
    slots are reproducible (seeded) and don't perturb greedy slots."""
    from repro.serve.engine import Request, SamplingParams, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(12)
    p0 = rng.integers(0, cfg.vocab_size, 6).astype(np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 4).astype(np.int32)

    def run():
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=2, max_len=32, packed=False, prefill_chunk=4))
        eng.submit(Request(uid=0, prompt=p0, max_new_tokens=5))
        eng.submit(Request(uid=1, prompt=p1, max_new_tokens=5,
                           sampling=SamplingParams(temperature=1.0,
                                                   top_k=5, seed=3)))
        return {r.uid: tuple(r.output) for r in eng.run_to_completion()}

    a, b = run(), run()
    assert a == b                                 # seeded => reproducible

    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=1, max_len=32, packed=False, prefill_chunk=4))
    eng.submit(Request(uid=0, prompt=p0, max_new_tokens=5))
    solo = eng.run_to_completion()[0]
    assert a[0] == tuple(solo.output)             # greedy slot unperturbed


def test_engine_backpressure_and_metrics():
    from repro.serve.engine import Request, ServingEngine
    cfg = float_cfg("stablelm-1.6b")
    params = lm.init_params(jax.random.PRNGKey(5), cfg)
    rng = np.random.default_rng(13)
    prompts = [rng.integers(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(3)]
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=1, max_len=32, packed=False, prefill_chunk=4,
        max_queue=2))
    assert eng.submit(Request(uid=0, prompt=prompts[0], max_new_tokens=2))
    assert eng.submit(Request(uid=1, prompt=prompts[1], max_new_tokens=2))
    assert not eng.submit(Request(uid=2, prompt=prompts[2],
                                  max_new_tokens=2))   # cap hit
    with pytest.raises(ValueError):                    # cache-capacity cap
        eng.submit(Request(
            uid=3, prompt=rng.integers(0, cfg.vocab_size, 30).astype(
                np.int32),
            max_new_tokens=16))
    done = eng.run_to_completion()
    assert len(done) == 2
    rep = eng.metrics.report()
    assert rep["rejected"] == 1
    assert rep["admitted"] == rep["retired"] == 2
    assert rep["prefill_tokens"] == 10                 # two 5-token prompts
    assert rep["generated_tokens"] == 4                # 2 reqs x 2 tokens
    # first token of each request is sampled inside a prefill pass; only
    # the second comes from a pure decode pass
    assert rep["decode_tokens"] == 2
    assert 0.0 < rep["occupancy"] <= 1.0
    assert rep["prefill_tok_s"] > 0 and rep["decode_tok_s"] > 0


def test_int8_kv_cache_decode_accuracy():
    """int8 KV cache (beyond-paper §Perf optimization) stays close to the
    full-precision decode path."""
    cfg = float_cfg("granite-3-8b")
    cfg = cfg.replace(quant=QuantConfig(enabled=False, kv_bits=8))
    rng = np.random.default_rng(7)
    params = lm.init_params(jax.random.PRNGKey(7), cfg)
    tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 12)), jnp.int32)
    caches = lm.init_caches(cfg, 2, 16, dtype=jnp.float32)
    assert caches[0]["attn"]["k"].dtype == jnp.int8
    full, _, _ = lm.forward(params, cfg, {"tokens": tokens})
    dec = _decode_all(cfg, params, tokens, max_len=16)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, -1]),
                               rtol=0.05, atol=0.05)

"""Pallas ulppack_conv2d / int_conv2d vs the lax conv oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import ops, ref
from repro.kernels.ulppack_conv2d import int_conv2d, ulppack_conv2d


def lattice(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2**bits, size=shape), jnp.int32)


CASES = [
    # (spec, N, H, W, C, Fh, Fw, Co)
    (PackSpec(1, 1, jnp.int16.dtype), 1, 12, 12, 8, 3, 3, 5),
    (PackSpec(2, 2, jnp.int16.dtype), 2, 10, 9, 16, 3, 3, 8),
    (PackSpec(3, 3, jnp.int16.dtype), 1, 9, 9, 6, 7, 7, 4),
    (PackSpec(1, 1, jnp.int8.dtype), 1, 11, 8, 10, 5, 5, 3),
]


class TestPackedConv2d:
    @pytest.mark.parametrize("spec,n,h,w,c,fh,fw,co", CASES,
                             ids=lambda v: str(v))
    def test_exact_valid(self, spec, n, h, w, c, fh, fw, co):
        rng = np.random.default_rng(c * 7 + fh)
        q_x = lattice(rng, (n, h, w, c), spec.a_bits)
        q_w = lattice(rng, (fh, fw, c, co), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        got = ulppack_conv2d(xp, wp, spec, block_co=4, padding="VALID",
                             interpret=True)
        want = ref.conv2d_i32_ref(q_x, q_w, padding="VALID")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_exact_same_padding(self):
        spec = PackSpec(2, 2, jnp.int16.dtype)
        rng = np.random.default_rng(0)
        q_x = lattice(rng, (1, 8, 8, 4), spec.a_bits)
        q_w = lattice(rng, (3, 3, 4, 6), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        got = ulppack_conv2d(xp, wp, spec, block_co=3, padding="SAME",
                             interpret=True)
        want = ref.conv2d_i32_ref(q_x, q_w, padding="SAME")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_xla_backend_agrees(self):
        spec = PackSpec(2, 2, jnp.int16.dtype)
        rng = np.random.default_rng(2)
        q_x = lattice(rng, (2, 9, 9, 8), spec.a_bits)
        q_w = lattice(rng, (3, 3, 8, 5), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        a = ops.packed_conv2d(xp, wp, spec, padding="VALID",
                              backend="pallas")
        b = ops.packed_conv2d(xp, wp, spec, padding="VALID", backend="xla")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestSpatialTiling:
    """Tiled grid (N, out_H/block_h, Co/block_co) is bit-exact for every
    block_h (incl. non-dividing tails), both paddings and both weight-storage
    modes — the acceptance bar for the halo-overlap schedule."""

    SPEC = PackSpec(2, 2, jnp.int16.dtype)

    @pytest.mark.parametrize("weight_store", ["lanes", "dense"])
    @pytest.mark.parametrize("padding", ["VALID", "SAME"])
    @pytest.mark.parametrize("block_h", [1, 2, 3, 4, 7, None])
    def test_tiled_exact(self, block_h, padding, weight_store):
        spec = self.SPEC
        rng = np.random.default_rng(11)
        n, h, w, c, fh, fw, co = 2, 9, 8, 6, 3, 3, 5
        q_x = lattice(rng, (n, h, w, c), spec.a_bits)
        q_w = lattice(rng, (fh, fw, c, co), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        if weight_store == "dense":
            wt = ops.dense_store_conv_weights(q_w, spec.w_bits)
            k_full = c
        else:
            wt = packing.pack_weights(q_w, spec, axis=2)
            k_full = None
        got = ulppack_conv2d(xp, wt, spec, block_h=block_h, block_co=2,
                             padding=padding, interpret=True,
                             weight_store=weight_store, k_full=k_full)
        want = ref.conv2d_i32_ref(q_x, q_w, padding=padding)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("weight_store", ["lanes", "dense"])
    def test_ops_entry_point_same_padding(self, weight_store):
        """SAME-padding parity through the planned ops entry point."""
        spec = self.SPEC
        rng = np.random.default_rng(5)
        q_x = lattice(rng, (1, 8, 8, 4), spec.a_bits)
        q_w = lattice(rng, (3, 3, 4, 6), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        if weight_store == "dense":
            wt = ops.dense_store_conv_weights(q_w, spec.w_bits)
            k_full = 4
        else:
            wt = packing.pack_weights(q_w, spec, axis=2)
            k_full = None
        want = ref.conv2d_i32_ref(q_x, q_w, padding="SAME")
        for backend in ("pallas", "xla"):
            got = ops.packed_conv2d(xp, wt, spec, padding="SAME",
                                    backend=backend,
                                    weight_store=weight_store, k_full=k_full)
            np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_planned_tiling_matches_full_slab(self):
        """A VMEM-squeezed plan (forced small block_h) equals the untiled
        result — the planner only changes the schedule, never the math."""
        from repro.kernels import plan as plan_lib

        spec = self.SPEC
        rng = np.random.default_rng(9)
        q_x = lattice(rng, (1, 16, 12, 8), spec.a_bits)
        q_w = lattice(rng, (5, 5, 8, 4), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        plan = plan_lib.plan_packed_conv2d(
            tuple(xp.shape), tuple(wp.shape), spec, padding="VALID",
            backend="pallas", vmem_budget=4 * 1024)
        assert plan.block_h < 12      # the budget actually forced tiling
        got = ops.packed_conv2d(xp, wp, spec, padding="VALID", plan=plan)
        want = ref.conv2d_i32_ref(q_x, q_w, padding="VALID")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestIntConv2d:
    @pytest.mark.parametrize("block_h", [None, 3, 8])
    def test_exact(self, block_h):
        rng = np.random.default_rng(4)
        q_x = jnp.asarray(rng.integers(-200, 200, (1, 10, 10, 7)), jnp.int16)
        q_w = jnp.asarray(rng.integers(-200, 200, (3, 3, 7, 5)), jnp.int16)
        got = int_conv2d(q_x, q_w, block_h=block_h, block_co=5,
                         padding="VALID", interpret=True)
        want = ref.conv2d_i32_ref(q_x.astype(jnp.int32),
                                  q_w.astype(jnp.int32), padding="VALID")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Pallas ulppack_conv2d / int_conv2d vs the lax conv oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import ops, ref
from repro.kernels.ulppack_conv2d import int_conv2d, ulppack_conv2d


def lattice(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2**bits, size=shape), jnp.int32)


CASES = [
    # (spec, N, H, W, C, Fh, Fw, Co)
    (PackSpec(1, 1, jnp.int16.dtype), 1, 12, 12, 8, 3, 3, 5),
    (PackSpec(2, 2, jnp.int16.dtype), 2, 10, 9, 16, 3, 3, 8),
    (PackSpec(3, 3, jnp.int16.dtype), 1, 9, 9, 6, 7, 7, 4),
    (PackSpec(1, 1, jnp.int8.dtype), 1, 11, 8, 10, 5, 5, 3),
]


class TestPackedConv2d:
    @pytest.mark.parametrize("spec,n,h,w,c,fh,fw,co", CASES,
                             ids=lambda v: str(v))
    def test_exact_valid(self, spec, n, h, w, c, fh, fw, co):
        rng = np.random.default_rng(c * 7 + fh)
        q_x = lattice(rng, (n, h, w, c), spec.a_bits)
        q_w = lattice(rng, (fh, fw, c, co), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        got = ulppack_conv2d(xp, wp, spec, block_co=4, padding="VALID",
                             interpret=True)
        want = ref.conv2d_i32_ref(q_x, q_w, padding="VALID")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_exact_same_padding(self):
        spec = PackSpec(2, 2, jnp.int16.dtype)
        rng = np.random.default_rng(0)
        q_x = lattice(rng, (1, 8, 8, 4), spec.a_bits)
        q_w = lattice(rng, (3, 3, 4, 6), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        got = ulppack_conv2d(xp, wp, spec, block_co=3, padding="SAME",
                             interpret=True)
        want = ref.conv2d_i32_ref(q_x, q_w, padding="SAME")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_xla_backend_agrees(self):
        spec = PackSpec(2, 2, jnp.int16.dtype)
        rng = np.random.default_rng(2)
        q_x = lattice(rng, (2, 9, 9, 8), spec.a_bits)
        q_w = lattice(rng, (3, 3, 8, 5), spec.w_bits)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)
        a = ops.packed_conv2d(xp, wp, spec, padding="VALID",
                              backend="pallas")
        b = ops.packed_conv2d(xp, wp, spec, padding="VALID", backend="xla")
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestIntConv2d:
    def test_exact(self):
        rng = np.random.default_rng(4)
        q_x = jnp.asarray(rng.integers(-200, 200, (1, 10, 10, 7)), jnp.int16)
        q_w = jnp.asarray(rng.integers(-200, 200, (3, 3, 7, 5)), jnp.int16)
        got = int_conv2d(q_x, q_w, block_co=5, padding="VALID",
                         interpret=True)
        want = ref.conv2d_i32_ref(q_x.astype(jnp.int32),
                                  q_w.astype(jnp.int32), padding="VALID")
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out DIR]
        [--autotune] [--update-baseline]

Emits ``name,us_per_call,derived`` style CSV blocks per benchmark plus the
aggregated roofline table from the dry-run reports, and persists each
benchmark's rows as ``BENCH_<key>.json`` under ``--out`` (the artifacts the
bench-smoke CI lane uploads and gates with benchmarks/compare.py).

``--autotune`` warm-tunes the benchmark kernel signatures missing from the
active autotune cache before running (winners persisted to
``reports/autotune_<device>.json`` — the tune-once-offline pass; the
nightly workflow runs it full-grid).  ``--update-baseline`` merges the
fresh BENCH_*.json payloads into ``reports/BENCH_baseline.json``, the
one-command refresh for the CI perf-regression gate (DESIGN.md §14).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
import time

BASELINE_PATH = os.path.join("reports", "BENCH_baseline.json")


def warm_tune(quick: bool) -> str:
    """Tune the bench kernel signatures missing from the active cache and
    persist it.  Shapes come from the bench modules themselves (fig4 conv,
    table2/serve linears) so a bench-shape change cannot silently desync
    the cache from the gate; --quick restricts to the CI-speed subset."""
    import jax.numpy as jnp

    from benchmarks import fig4_conv2d, serve_microbench, \
        table2_kernel_report
    from repro.core.packing import PackSpec
    from repro.kernels import autotune

    spec = PackSpec(2, 2, jnp.int16.dtype)
    cin, co, f = fig4_conv2d.CIN, fig4_conv2d.COUT, fig4_conv2d.FH
    cp = -(-cin // spec.n_pack)
    # fig4/table2 conv shapes: the full grid covers quick AND full
    # resolutions so one nightly pass refreshes every gated shape
    hws = (fig4_conv2d.QUICK_HW,) if quick \
        else (fig4_conv2d.QUICK_HW, fig4_conv2d.H)
    per = 32 // spec.w_bits
    for hw in hws:
        for store, cdim in (("lanes", cp), ("dense", -(-cin // per))):
            autotune.tune_packed_conv2d(
                (1, hw, hw, cp), (f, f, cdim, co), spec, padding="VALID",
                backend="pallas", weight_store=store,
                k_full=cin if store == "dense" else None)
        # lane-layout axis (PackSpec family sweep, DESIGN.md §16): tiles
        # per candidate land in the same cache via tune_packed_conv2d
        autotune.tune_conv2d_layout((1, hw, hw, cin), (f, f, cin, co),
                                    spec, padding="VALID", backend="pallas")
    # decode-shaped serving linears (pallas tile grid); full adds the
    # table2 decode linear
    shapes = [serve_microbench.TUNED_LINEAR_SHAPE, (8, 1024, 1024)]
    if not quick:
        shapes.append((table2_kernel_report.M, table2_kernel_report.K,
                       table2_kernel_report.N))
    for m, k, n in shapes:
        autotune.tune_packed_matmul(m, -(-k // spec.n_pack), n, spec,
                                    backend="pallas")
        autotune.tune_matmul_layout(m, k, n, spec, backend="pallas")
    if not quick:
        autotune.tune_attention_chunk(2, 64, 64, 4, 2, 64, kv_bits=4)
        autotune.tune_attention_chunk(2, 64, 64, 4, 2, 64, kv_bits=0)
    # fused decode-attention kv-split grid (DESIGN.md §20): both gated
    # serve_microbench.run_attention_decode shapes (paged + contiguous)
    ab, askv, ah, akvh, ahd, abits, aps = serve_microbench.ATTN_DECODE_SHAPE
    autotune.tune_attention_decode(ab, askv, ah, akvh, ahd, kv_bits=abits,
                                   page_size=aps, backend="xla")
    autotune.tune_attention_decode(ab, askv, ah, akvh, ahd, kv_bits=abits,
                                   backend="xla")
    if not quick:
        for bits in (0, 4):              # nightly full grid: float + 4-bit
            autotune.tune_attention_decode(ab, askv, ah, akvh, ahd,
                                           kv_bits=bits, page_size=aps,
                                           backend="xla")
            autotune.tune_attention_decode(ab, askv, ah, akvh, ahd,
                                           kv_bits=bits, backend="xla")
    return autotune.active_cache().save()


def update_baseline(out_dir: str, quick: bool, keys) -> str:
    """Merge the BENCH_*.json files under ``out_dir`` into the committed
    gate baseline (reports/BENCH_baseline.json); benches not re-run this
    invocation (--only) keep their previous baseline entries."""
    from benchmarks.common import BENCH_SCHEMA
    from benchmarks.compare import load_payloads

    fresh = load_payloads(out_dir)
    merged = {}
    if os.path.exists(BASELINE_PATH):
        try:
            merged = load_payloads(BASELINE_PATH)
        except (OSError, ValueError):
            merged = {}
    merged.update({k: v for k, v in fresh.items() if not keys or k in keys})
    os.makedirs(os.path.dirname(BASELINE_PATH), exist_ok=True)
    payload = {"schema": BENCH_SCHEMA, "quick": quick, "benches": merged}
    with open(BASELINE_PATH, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return BASELINE_PATH


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma-list: fig4,fig5,table2,roofline,serve")
    ap.add_argument("--out", default="bench-out",
                    help="directory for BENCH_<key>.json result files "
                         "(kept out of the repo root so stale artifacts "
                         "never shadow the bench-out/ CI uploads)")
    ap.add_argument("--autotune", action="store_true",
                    help="warm-tune the bench kernel signatures into the "
                         "persisted autotune cache before running")
    ap.add_argument("--update-baseline", action="store_true",
                    help=f"merge the fresh results into {BASELINE_PATH} "
                         "(the CI perf-regression gate baseline)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig4_conv2d, fig5_precision_sweep,
                            roofline_table, serve_microbench,
                            table2_kernel_report)
    from benchmarks.common import write_bench_json

    if args.autotune:
        print(f"# autotune cache saved to {warm_tune(args.quick)}")

    benches = [
        ("fig4_conv2d  [paper Fig.4: conv2d impl comparison]",
         "fig4", fig4_conv2d.run),
        ("fig5_precision_sweep  [paper Fig.5: (W,A) region + speedups]",
         "fig5", fig5_precision_sweep.run),
        ("table2_kernel_report  [paper Table II analogue: kernel report]",
         "table2", table2_kernel_report.run),
        ("serve_microbench  [packed serving linears + engine-level "
         "chunked-prefill vs token-at-a-time]",
         "serve", serve_microbench.run),
        ("roofline_table  [assignment: 40-cell dry-run aggregate]",
         "roofline", roofline_table.run),
    ]
    failures = 0
    ran = []
    for title, key, fn in benches:
        if only and key not in only:
            continue
        print(f"\n=== {title} ===")
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
            dt = time.time() - t0
            if rows:
                path = write_bench_json(
                    key, {"bench": key, "quick": args.quick,
                          "seconds": round(dt, 2), "rows": rows},
                    args.out)
                print(f"# wrote {path}")
                ran.append(key)
            print(f"# done in {dt:.1f}s")
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"# FAILED: {type(e).__name__}: {e}")
    if args.update_baseline and ran:
        path = update_baseline(args.out, args.quick, set(ran))
        print(f"\n# gate baseline refreshed: {path}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).

    PYTHONPATH=src python -m benchmarks.run [--quick] [--out DIR]

Emits ``name,us_per_call,derived`` style CSV blocks per benchmark plus the
aggregated roofline table from the dry-run reports, and persists each
benchmark's rows as ``BENCH_<key>.json`` under ``--out`` (the artifacts the
bench-smoke CI lane uploads so perf trajectory is recorded per PR).
"""

from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes (CI-speed)")
    ap.add_argument("--only", default="",
                    help="comma-list: fig4,fig5,table2,roofline,serve")
    ap.add_argument("--out", default=".",
                    help="directory for BENCH_<key>.json result files")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from benchmarks import (fig4_conv2d, fig5_precision_sweep,
                            roofline_table, serve_microbench,
                            table2_kernel_report)
    from benchmarks.common import write_bench_json

    benches = [
        ("fig4_conv2d  [paper Fig.4: conv2d impl comparison]",
         "fig4", fig4_conv2d.run),
        ("fig5_precision_sweep  [paper Fig.5: (W,A) region + speedups]",
         "fig5", fig5_precision_sweep.run),
        ("table2_kernel_report  [paper Table II analogue: kernel report]",
         "table2", table2_kernel_report.run),
        ("serve_microbench  [packed serving linears + engine-level "
         "chunked-prefill vs token-at-a-time]",
         "serve", serve_microbench.run),
        ("roofline_table  [assignment: 40-cell dry-run aggregate]",
         "roofline", roofline_table.run),
    ]
    failures = 0
    for title, key, fn in benches:
        if only and key not in only:
            continue
        print(f"\n=== {title} ===")
        t0 = time.time()
        try:
            rows = fn(quick=args.quick)
            dt = time.time() - t0
            if rows:
                path = write_bench_json(
                    key, {"bench": key, "quick": args.quick,
                          "seconds": round(dt, 2), "rows": rows},
                    args.out)
                print(f"# wrote {path}")
            print(f"# done in {dt:.1f}s")
        except Exception as e:  # keep the harness running
            failures += 1
            print(f"# FAILED: {type(e).__name__}: {e}")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()

"""Aggregate reports/dryrun/*.json into the §Roofline table (markdown+CSV)."""

from __future__ import annotations

import json
from pathlib import Path

REPORT_DIR = Path(__file__).resolve().parents[1] / "reports" / "dryrun"
OUT_MD = Path(__file__).resolve().parents[1] / "reports" / "roofline.md"

COLS = ["arch", "shape", "mesh", "status", "compute_s", "memory_s",
        "collective_s", "dominant", "compute_floor_s", "useful_ratio",
        "temp_gib", "compile_s"]


def load_rows():
    """Aggregate cell JSONs with trip-count correction.

    XLA cost analysis counts while-loop bodies ONCE.  The train step's outer
    loop is the microbatch-accumulation scan with a statically known trip
    count (cfg.parallel.microbatches) — we scale all three per-step terms by
    it.  The q-chunked attention map still undercounts attention FLOPs, so we
    also report `compute_floor_s` = analytic MODEL_FLOPS/(chips*peak), and
    the dominant term uses max(compute, compute_floor).
    """
    from repro import configs
    from repro.roofline import hw

    rows = []
    if not REPORT_DIR.exists():
        return rows
    for f in sorted(REPORT_DIR.glob("*.json")):
        d = json.loads(f.read_text())
        temp = d.get("memory_analysis", {}).get("temp_size_in_bytes")
        scale = 1
        if d.get("status") == "OK" and d.get("shape") == "train_4k":
            try:
                scale = max(1, configs.get_config(
                    d["arch"]).parallel.microbatches)
            except KeyError:
                pass
        comp = (d.get("compute_s") or 0) * scale
        mem = (d.get("memory_s") or 0) * scale
        coll = (d.get("collective_s") or 0) * scale
        mesh_name = d.get("mesh") or "16x16"
        chips = 1
        for x in mesh_name.split("x"):
            chips *= int(x)
        mflops = d.get("model_flops") or 0
        floor = mflops / (chips * hw.PEAK_FLOPS_BF16) if mflops else 0
        hlo_flops = (d.get("hlo_flops_per_device") or 0) * scale
        useful = mflops / (hlo_flops * chips) if hlo_flops else None
        dom = ""
        if d.get("status") == "OK":
            vals = {"compute": max(comp, floor), "memory": mem,
                    "collective": coll}
            dom = max(vals, key=vals.get)
        rows.append({
            "arch": d.get("arch"), "shape": d.get("shape"),
            "mesh": d.get("mesh"), "status": d.get("status"),
            "compute_s": _f(comp) if d.get("status") == "OK" else "",
            "memory_s": _f(mem) if d.get("status") == "OK" else "",
            "collective_s": _f(coll) if d.get("status") == "OK" else "",
            "dominant": dom,
            "compute_floor_s": _f(floor) if d.get("status") == "OK" else "",
            "useful_ratio": _f(useful),
            "temp_gib": round(temp / 2**30, 2) if temp else "",
            "compile_s": d.get("compile_s", ""),
            "error": (d.get("error") or "")[:80],
        })
    return rows


def _f(x):
    if x is None:
        return ""
    try:
        v = float(x)
    except (TypeError, ValueError):
        return ""
    if v == 0:
        return 0.0
    return float(f"{v:.4g}")


def run(quick: bool = False):
    del quick
    rows = load_rows()
    print(",".join(COLS))
    for r in rows:
        print(",".join(str(r.get(c, "")) for c in COLS))
    # markdown table
    lines = ["| " + " | ".join(COLS) + " |",
             "|" + "---|" * len(COLS)]
    for r in rows:
        lines.append("| " + " | ".join(str(r.get(c, "")) for c in COLS)
                     + " |")
    OUT_MD.parent.mkdir(parents=True, exist_ok=True)
    OUT_MD.write_text("\n".join(lines) + "\n")
    print(f"# wrote {OUT_MD} ({len(rows)} cells)")
    return rows


if __name__ == "__main__":
    run()

"""Paper Table II analogue: physical-implementation report, TPU edition.

Table II reports silicon area/power/fmax for the Ara vs Sparq lane — no TPU
analogue exists (DESIGN.md §7).  The deployment-relevant counterparts we CAN
measure from the compiled artifacts:

  * HLO op census of the serving linear: the packed path's inner loop is
    integer-only (the paper's "FPU removal" maps to float-free inner
    compute; floats only in the final dequant epilogue),
  * kernel VMEM working set per BlockSpec (must fit the 16 MiB v5e budget),
  * bytes/FLOP (arithmetic intensity) per path,
  * serving parameter bytes: bf16 vs packed-int16 lanes vs bit-dense storage
    (the area-per-op analogue: HBM footprint per weight).
"""

from __future__ import annotations

import re

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, normalize_cost, record
from repro.core.packing import PackSpec
from repro.kernels import autotune
from repro.kernels import ops
from repro.kernels import plan as plan_lib

M, K, N = 8, 2048, 2048   # decode-shaped linear


def _census(fn, *args):
    txt = jax.jit(fn).lower(*args).compile().as_text()
    fl = len(re.findall(r"\b(f32|bf16|f16)\[", txt))
    it = len(re.findall(r"\b(s8|s16|s32|u8|u16|u32)\[", txt))
    c = normalize_cost(jax.jit(fn).lower(*args).compile().cost_analysis())
    return {"float_type_mentions": fl, "int_type_mentions": it,
            "flops": float(c.get("flops", 0) or 0),
            "bytes": float(c.get("bytes accessed", 0) or 0)}


def run(quick: bool = False):
    del quick
    rng = np.random.default_rng(0)
    rows = []
    spec = PackSpec(2, 2, jnp.int16.dtype)
    x = jnp.asarray(rng.normal(size=(M, K)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(K, N)) * 0.05, jnp.float32)
    wp, cs = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(2), spec)

    # bf16 baseline linear
    wb = w.astype(jnp.bfloat16)

    def bf16_linear(x, wb):
        return jnp.dot(x.astype(jnp.bfloat16), wb)

    c = _census(bf16_linear, x, wb)
    rows.append({"path": "bf16-linear", **c,
                 "intensity_flops_per_byte": round(c["flops"]
                                                   / max(c["bytes"], 1), 3),
                 "weight_bytes": wb.size * 2})

    # packed integer core (the Sparq path without dequant epilogue)
    ap = ops.quantize_pack(x, jnp.float32(0.07), jnp.int32(2), spec,
                           backend="xla")[0]

    def packed_core(ap, wp):
        return ops.packed_matmul(ap, wp, spec, backend="xla")

    c = _census(packed_core, ap, wp)
    rows.append({"path": "packed-int-core(W2A2)", **c,
                 "intensity_flops_per_byte": round(c["flops"]
                                                   / max(c["bytes"], 1), 3),
                 "weight_bytes": wp.size * 2})

    # full deployed linear (pack + matmul + affine dequant)
    def deployed(x, wp, cs):
        return ops.quantized_linear(x, wp, cs, jnp.float32(0.07),
                                    jnp.int32(2), jnp.float32(0.02),
                                    jnp.int32(2), spec, backend="xla")

    c = _census(deployed, x, wp, cs)
    rows.append({"path": "deployed-linear(W2A2)", **c,
                 "intensity_flops_per_byte": round(c["flops"]
                                                   / max(c["bytes"], 1), 3),
                 "weight_bytes": wp.size * 2})

    # bit-dense storage variant (beyond-paper): true 2 bits/weight in HBM
    from repro.core import quant as quant_lib
    q_w = quant_lib.quantize_affine(w, jnp.float32(0.02), 2, 2)
    dense_words = ops.dense_store_weights(q_w, 2)
    rows.append({"path": "bit-dense-weights(W2)", "float_type_mentions": 0,
                 "int_type_mentions": 0, "flops": 0, "bytes": 0,
                 "intensity_flops_per_byte": "",
                 "weight_bytes": dense_words.size * 4})

    # kernel VMEM working sets: the planner's chosen plans vs the 16 MiB
    # v5e budget (plan.py sizes every BlockSpec offline)
    kp = -(-K // spec.n_pack)
    mm_plan = plan_lib.plan_packed_matmul(M, kp, N, spec, backend="pallas")
    conv_plan = plan_lib.plan_packed_conv2d(
        (1, 256, 256, 16), (7, 7, 16, 32), spec, padding="VALID",
        backend="pallas")
    conv_dense_plan = plan_lib.plan_packed_conv2d(
        (1, 256, 256, 16), (7, 7, 2, 32), spec, padding="VALID",
        backend="pallas", weight_store="dense", k_full=32)
    for plan in (mm_plan, conv_plan, conv_dense_plan):
        rows.append({"path": str(plan),
                     "float_type_mentions": 0, "int_type_mentions": 0,
                     "flops": 0, "bytes": plan.vmem_bytes,
                     "intensity_flops_per_byte":
                         f"vmem_frac={plan.vmem_fraction:.3f}",
                     "weight_bytes": ""})

    emit(rows, ["path", "flops", "bytes", "intensity_flops_per_byte",
                "float_type_mentions", "int_type_mentions", "weight_bytes"])
    rows += _autotune_report(spec, kp)
    rows += _layout_report(spec)
    return rows


def _autotune_report(spec, kp):
    """Heuristic-vs-tuned per planned signature, straight from the autotune
    cache (entries persist the measured winner + heuristic timing, so this
    report costs no re-measurement; DESIGN.md §14)."""
    keys = {
        "matmul-decode": autotune.matmul_key(M, kp, N, spec,
                                             backend="pallas"),
        "conv-lanes": autotune.conv2d_key(
            (1, 256, 256, 16), (7, 7, 16, 32), spec, padding="VALID",
            backend="pallas"),
        "conv-dense": autotune.conv2d_key(
            (1, 256, 256, 16), (7, 7, 2, 32), spec, padding="VALID",
            backend="pallas", weight_store="dense"),
    }
    rows = []
    for name, key in keys.items():
        entry = autotune.lookup(key)
        if entry is None:
            rows.append(record(f"autotune/{name}", plan_source="heuristic",
                               tuned_speedup=1.0))
            continue
        heur_us = entry.get("heuristic_us") or 0.0
        tuned_us = entry.get("wall_us") or 0.0
        rows.append(record(
            f"autotune/{name}", plan_source="tuned",
            tuned_us=tuned_us, heuristic_us=heur_us,
            tuned_speedup=round(heur_us / tuned_us, 2) if tuned_us else 1.0,
            vmem_bytes=entry.get("vmem_bytes", 0),
            candidates=entry.get("candidates", 0)))
    emit(rows, ["case", "plan_source", "heuristic_us", "tuned_us",
                "tuned_speedup", "vmem_bytes", "candidates"])
    return rows


def _layout_report(spec):
    """Lane-layout sweep winners (DESIGN.md §16), straight from the layout
    cache: per signature, the chosen PackSpec and its measured win over the
    config-default layout (``layout_speedup`` = base_us / wall_us; both
    values were measured by ``tune_*_layout`` with tuned tiles, so this
    report costs no re-measurement).  A cache miss reports the config
    default at 1.0 — the fixed-layout behavior."""
    from benchmarks import fig4_conv2d as fig4

    keys = {
        "matmul-decode": autotune.matmul_layout_key(
            K, N, spec.w_bits, spec.a_bits, backend="pallas"),
        "conv-lanes": autotune.conv2d_layout_key(
            (1, fig4.H, fig4.H, fig4.CIN),
            (fig4.FH, fig4.FW, fig4.CIN, fig4.COUT), spec.w_bits,
            spec.a_bits, padding="VALID", backend="pallas"),
    }
    rows = []
    for name, key in keys.items():
        entry = autotune.lookup(key)
        if entry is None:
            rows.append(record(f"layout/{name}", spec=str(spec),
                               base_spec=str(spec), layout_speedup=1.0,
                               candidates=0))
            continue
        wall_us = entry.get("wall_us") or 0.0
        base_us = entry.get("base_us") or 0.0
        rows.append(record(
            f"layout/{name}", spec=entry.get("spec", str(spec)),
            base_spec=entry.get("base_spec", str(spec)),
            wall_us=wall_us, base_us=base_us,
            layout_speedup=(round(base_us / wall_us, 2)
                            if wall_us and base_us else 1.0),
            candidates=entry.get("candidates", 0)))
    emit(rows, ["case", "spec", "base_spec", "base_us", "wall_us",
                "layout_speedup", "candidates"])
    return rows


if __name__ == "__main__":
    run()

"""Paper Fig. 4 reproduction: conv2d throughput across implementations.

Paper setting: 7x7 kernel, channel-first 32x256x256 input, impls =
{int16 baseline, W3A3/W2A2/W1A1 native ULPPACK, LP/ULP with vmacsr}.

On this CPU container we report, per implementation:
  * useful MACs (the conv's mathematical work),
  * compiled HLO FLOPs (XLA counts the packed contraction at K/2 — the
    paper's "ops/cycle" gain made visible in the compiled artifact),
  * measured CPU wall-clock (the packed path does half the multiplies of
    int16 and it shows up on CPU too),
  * the instruction-count model of §IV (vmacc vs vmacsr issue counts) which
    carries the Ara-vs-Sparq distinction that XLA cannot express,
  * modeled speedup vs int16 from that instruction model, compared with the
    paper's measured 3.2x (<=2-bit) and 1.7x (<=4-bit).
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import (cost_of, emit, record,
                               tuned_vs_heuristic_row, wall_us)
from repro.core import packing, vmacsr
from repro.core.packing import PackSpec
from repro.kernels import ops, ref
from repro.kernels import plan as plan_lib
from repro.kernels.ulppack_conv2d import ulppack_conv2d

H = W = 256
QUICK_HW = 64          # --quick spatial size (CI lane)
CIN = 32
COUT = 32
FH = FW = 7


def _lattice(rng, shape, bits):
    return jnp.asarray(rng.integers(0, 2 ** bits, size=shape), jnp.int32)


def _useful_macs(out_h, out_w):
    return out_h * out_w * FH * FW * CIN * COUT


def run(quick: bool = False):
    global H, W
    if quick:
        h = w = QUICK_HW
    else:
        h = w = H
    rng = np.random.default_rng(0)
    rows = []
    out_h, out_w = h - FH + 1, w - FW + 1
    macs = _useful_macs(out_h, out_w)

    # --- int16 baseline (paper §III-A) ---
    q_x16 = jnp.asarray(rng.integers(-256, 256, (1, h, w, CIN)), jnp.int16)
    q_w16 = jnp.asarray(rng.integers(-256, 256, (FH, FW, CIN, COUT)),
                        jnp.int16)

    def int16_conv(x, wt):
        return ref.conv2d_i32_ref(x, wt, padding="VALID")

    base_cost = cost_of(int16_conv, q_x16, q_w16)
    base_us = wall_us(int16_conv, q_x16, q_w16, iters=2)
    base_row = {
        "impl": "int16-conv2d", "w_bits": 16, "a_bits": 16,
        "wall_us": round(base_us, 1), "hlo_flops": base_cost["flops"],
        "useful_macs": macs,
        "instr_per_k": vmacsr.int16_instruction_count(CIN).total,
        "modeled_speedup": 1.0, "measured_speedup": 1.0,
        "paper_speedup": 1.0,
    }
    rows.append(base_row)

    cases = [
        ("W3A3-native", 3, 3, "native"),
        ("W2A2-native", 2, 2, "native"),
        ("W1A1-native", 1, 1, "native"),
        ("LP-vmacsr(W3A3)", 3, 3, "fused"),
        ("ULP-vmacsr(W2A2)", 2, 2, "fused"),
        ("ULP-vmacsr(W1A1)", 1, 1, "fused"),
    ]
    paper = {"ULP-vmacsr(W2A2)": 3.2, "LP-vmacsr(W3A3)": 1.7}

    for name, wb, ab, mode in cases:
        lane = jnp.int8.dtype if (mode == "fused" and wb + ab <= 2) \
            else jnp.int16.dtype
        spec = PackSpec(wb, ab, lane)
        if not spec.feasible:
            lane = jnp.int16.dtype
            spec = PackSpec(wb, ab, lane)
        q_x = _lattice(rng, (1, h, w, CIN), ab)
        q_w = _lattice(rng, (FH, FW, CIN, COUT), wb)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        wp = packing.pack_weights(q_w, spec, axis=2)

        plan = plan_lib.plan_packed_conv2d(
            tuple(xp.shape), tuple(wp.shape), spec, padding="VALID",
            backend="xla")

        def packed(xp, wp, spec=spec, plan=plan):
            return ops.packed_conv2d(xp, wp, spec, padding="VALID",
                                     plan=plan)

        c = cost_of(packed, xp, wp)
        us = wall_us(packed, xp, wp, iters=3)
        # instruction model per output element over the K=Fh*Fw*Cin loop
        k = FH * FW * CIN
        if mode == "native":
            ic = vmacsr.native_ulppack_instruction_count(k, spec.k_tile,
                                                         spec.n_pack)
        else:
            ic = vmacsr.vmacsr_instruction_count(k, spec.k_tile, spec.n_pack)
        # lane-width factor: int8 lanes fit 2x more elements per vector reg
        width_gain = 2 if spec.lane_dtype == jnp.int8.dtype else 1
        modeled = (vmacsr.int16_instruction_count(k).total /
                   ic.total) * width_gain
        rows.append({
            "impl": name, "w_bits": wb, "a_bits": ab,
            "wall_us": round(us, 1), "hlo_flops": c["flops"],
            "useful_macs": macs,
            "instr_per_k": ic.total,
            "modeled_speedup": round(modeled, 2),
            "measured_speedup": round(base_us / us, 2),
            "paper_speedup": paper.get(name, ""),
            "plan": str(plan),
        })

    emit(rows, ["impl", "w_bits", "a_bits", "wall_us", "hlo_flops",
                "useful_macs", "instr_per_k", "modeled_speedup",
                "measured_speedup", "paper_speedup", "plan"])
    _sweep_block_h(rng, h, w, quick)
    rows += _tuned_vs_heuristic(rng, h, w)
    rows += _layout_sweep(rng, h, w)
    return rows


def _sweep_block_h(rng, h, w, quick):
    """Spatial-tiling sweep of the Pallas kernel (W2A2, both weight stores).

    Shows the VMEM-boundedness of the tiled schedule: working set scales
    with block_h, not the image, while staying bit-exact (the plan's own
    estimate is reported alongside measured wall time).
    """
    spec = PackSpec(2, 2, jnp.int16.dtype)
    q_x = _lattice(rng, (1, h, w, CIN), spec.a_bits)
    q_w = _lattice(rng, (FH, FW, CIN, COUT), spec.w_bits)
    xp = packing.pack_activations(q_x, spec, axis=-1)
    wp = packing.pack_weights(q_w, spec, axis=2)
    wd = ops.dense_store_conv_weights(q_w, spec.w_bits)
    out_h = h - FH + 1
    blocks = [8, 32] if quick else [16, 64, 256]
    rows = []
    for store, wt in (("lanes", wp), ("dense", wd)):
        for bh in blocks + [None]:
            plan = plan_lib.plan_packed_conv2d(
                tuple(xp.shape), tuple(wt.shape), spec, padding="VALID",
                backend="pallas", weight_store=store,
                k_full=CIN if store == "dense" else None, block_h=bh)

            def tiled(xp, wt, plan=plan):
                return ulppack_conv2d(
                    xp, wt, plan.spec, block_h=plan.block_h,
                    block_co=plan.block_co, padding="VALID",
                    interpret=plan.interpret, weight_store=plan.weight_store,
                    k_full=plan.k_full)

            us = wall_us(tiled, xp, wt, iters=1, warmup=1)
            rows.append({
                "weight_store": store,
                "block_h": plan.block_h,
                "tiles": -(-out_h // plan.block_h),
                "vmem_bytes": plan.vmem_bytes,
                "vmem_frac": round(plan.vmem_fraction, 4),
                "wall_us": round(us, 1),
                "plan": str(plan),
            })
    emit(rows, ["weight_store", "block_h", "tiles", "vmem_bytes",
                "vmem_frac", "wall_us", "plan"])


def _tuned_vs_heuristic(rng, h, w):
    """Autotuned plan vs the static heuristic at the paper's conv shape
    (both weight stores), measured through the same Pallas dispatch.  On a
    cache miss the tuned plan IS the heuristic (source='heuristic',
    speedup 1.0) — the row then records that no tuning data was available
    (DESIGN.md §14)."""
    spec = PackSpec(2, 2, jnp.int16.dtype)
    q_x = _lattice(rng, (1, h, w, CIN), spec.a_bits)
    q_w = _lattice(rng, (FH, FW, CIN, COUT), spec.w_bits)
    xp = packing.pack_activations(q_x, spec, axis=-1)
    wp = packing.pack_weights(q_w, spec, axis=2)
    wd = ops.dense_store_conv_weights(q_w, spec.w_bits)
    rows = []
    for store, wt in (("lanes", wp), ("dense", wd)):
        kw = dict(padding="VALID", backend="pallas", weight_store=store,
                  k_full=CIN if store == "dense" else None)
        heur = plan_lib.plan_packed_conv2d(
            tuple(xp.shape), tuple(wt.shape), spec,
            use_tuning_cache=False, **kw)
        tuned = plan_lib.plan_packed_conv2d(
            tuple(xp.shape), tuple(wt.shape), spec, **kw)
        rows.append(tuned_vs_heuristic_row(
            f"tuned-vs-heuristic/{store}", heur, tuned,
            lambda plan, wt=wt: ops.packed_conv2d(
                xp, wt, spec, padding="VALID", plan=plan)))
    emit(rows, ["case", "heuristic_us", "tuned_us", "tuned_speedup",
                "plan_source", "plan"])
    return rows


def _layout_sweep(rng, h, w):
    """Chosen lane layout vs the fixed-layout heuristic at the paper's conv
    shape (W2A2, lanes store), measured through the same Pallas dispatch.

    The candidate layout comes from the committed layout cache
    (autotune.conv2d_layout_for; warm-tuned by ``benchmarks.run
    --autotune``); each side packs its own weights — the offline decision
    this axis tunes.  On a layout-cache miss the chosen spec IS the config
    default (speedup 1.0).  The chosen layout's output is asserted
    bit-exact against the unpacked int32 reference before it is timed
    (DESIGN.md §16)."""
    from repro.kernels import autotune

    base = PackSpec(2, 2, jnp.int16.dtype)
    q_x = _lattice(rng, (1, h, w, CIN), base.a_bits)
    q_w = _lattice(rng, (FH, FW, CIN, COUT), base.w_bits)
    want = np.asarray(ref.conv2d_i32_ref(q_x, q_w, padding="VALID"))
    chosen = autotune.conv2d_layout_for(
        (1, h, w, CIN), (FH, FW, CIN, COUT), base, padding="VALID",
        backend="pallas", weight_store="lanes")

    def operands(spec):
        return (packing.pack_activations(q_x, spec, axis=-1),
                packing.pack_weights(q_w, spec, axis=2))

    kw = dict(padding="VALID", backend="pallas", weight_store="lanes")
    xb, wb = operands(base)
    heur = plan_lib.plan_packed_conv2d(tuple(xb.shape), tuple(wb.shape),
                                       base, use_tuning_cache=False, **kw)
    heur_us = wall_us(lambda: ops.packed_conv2d(
        xb, wb, base, padding="VALID", plan=heur), iters=1, warmup=1)
    xc, wc = operands(chosen)
    tuned = plan_lib.plan_packed_conv2d(tuple(xc.shape), tuple(wc.shape),
                                        chosen, **kw)
    got = ops.packed_conv2d(xc, wc, chosen, padding="VALID", plan=tuned)
    np.testing.assert_array_equal(np.asarray(got), want)
    tuned_us = heur_us if (chosen, tuned) == (base, heur) else wall_us(
        lambda: ops.packed_conv2d(xc, wc, chosen, padding="VALID",
                                  plan=tuned), iters=1, warmup=1)
    rows = [record("layout-sweep/lanes",
                   heuristic_us=round(heur_us, 1),
                   tuned_us=round(tuned_us, 1),
                   tuned_speedup=round(heur_us / tuned_us, 2),
                   spec=str(chosen), base_spec=str(base),
                   plan_source=tuned.source, plan=str(tuned))]
    emit(rows, ["case", "heuristic_us", "tuned_us", "tuned_speedup",
                "spec", "base_spec", "plan_source", "plan"])
    return rows


if __name__ == "__main__":
    run()

"""Shared benchmark helpers: timing, cost analysis, CSV + JSON emission,
and the standardized record schema the CI perf-regression gate consumes
(benchmarks/compare.py; DESIGN.md §14)."""

from __future__ import annotations

import json
import os

import jax
import numpy as np

#: Version of the BENCH_*.json payload layout ({"bench", "schema", "rows"}).
#: compare.py refuses to gate across schema versions.
BENCH_SCHEMA = 1


def wall_us(fn, *args, iters: int = 5, warmup: int = 2, repeats: int = 3,
            min_time_s: float = 0.01) -> float:
    """Median-of-``repeats`` wall time per call in microseconds.

    Delegates to kernels/autotune.measure_us so benchmarks and the
    autotuner share one timing methodology: each sample times a batch of
    calls whose size starts at ``iters`` and doubles until a batch takes at
    least ``min_time_s`` — fixed-iteration timing at timer resolution is
    what made the old ``iters=5`` numbers flake on noisy CI runners.
    """
    from repro.kernels.autotune import measure_us

    return measure_us(fn, *args, repeats=repeats, min_time_s=min_time_s,
                      iters=iters, warmup=warmup)


def normalize_cost(c) -> dict:
    """cost_analysis() returns a dict, a per-device list of dicts, or None
    depending on jax version/backend — normalize to one dict."""
    if isinstance(c, (list, tuple)):
        c = c[0] if c else None
    return c or {}


def cost_of(fn, *args) -> dict:
    """flops / bytes accessed of the jitted fn at these args."""
    c = normalize_cost(jax.jit(fn).lower(*args).compile().cost_analysis())
    return {"flops": float(c.get("flops", 0.0) or 0.0),
            "bytes": float(c.get("bytes accessed", 0.0) or 0.0)}


# ---------------------------------------------------------------------------
# Record schema: every bench row should carry a stable case identity so two
# runs can be diffed row-by-row.  New rows use ``record``; ``row_case``
# derives an identity for rows predating the schema.
# ---------------------------------------------------------------------------

#: Legacy identity keys, in lookup order (fig4 'impl', table2/serve 'path',
#: engine 'engine', kv sweep 'kv_bits', ...), then composite identities
#: (fig5 precision grid, roofline cells).
_CASE_KEYS = ("case", "impl", "path", "engine", "kv_bits", "name", "cell")
_CASE_GROUPS = (("mode", "w_bits", "a_bits"), ("arch", "shape", "mesh"),
                ("weight_store", "block_h"))


def record(case: str, **fields) -> dict:
    """One standardized bench row: a stable ``case`` id + metric fields."""
    return {"case": str(case), **fields}


def row_case(row: dict, index: int = 0) -> str:
    """Stable identity of a bench row (falls back to its position)."""
    for key in _CASE_KEYS:
        if key in row:
            return f"{key}={row[key]}" if key != "case" else str(row[key])
    for group in _CASE_GROUPS:
        if all(k in row for k in group):
            return "|".join(f"{k}={row[k]}" for k in group)
    return f"row{index}"


def tuned_vs_heuristic_row(case: str, heur_plan, tuned_plan,
                           run_plan) -> dict:
    """The standard tuned-vs-heuristic record (fig4 conv, serve linear):
    time ``run_plan(plan)`` under both plans and emit the gate-facing
    speedup.  On a cache miss the tuned plan equals the heuristic, so it
    is timed once and the speedup is exactly 1.0 (DESIGN.md §14)."""
    heur_us = wall_us(lambda: run_plan(heur_plan), iters=1, warmup=1)
    tuned_us = heur_us if tuned_plan == heur_plan else \
        wall_us(lambda: run_plan(tuned_plan), iters=1, warmup=1)
    return record(case,
                  heuristic_us=round(heur_us, 1),
                  tuned_us=round(tuned_us, 1),
                  tuned_speedup=round(heur_us / tuned_us, 2),
                  plan_source=tuned_plan.source, plan=str(tuned_plan))


#: Metric direction rules: suffix/substring -> better direction.  Metrics
#: matching neither are informational (never compared numerically).
_LOWER_BETTER = ("_us", "_bytes", "_seconds", "seconds", "instr_per_k",
                 "mean_admission_wait_s", "cache_bytes_per_slot")
_HIGHER_BETTER = ("tok_s", "speedup", "_vs_bf16", "slots", "occupancy")


def metric_direction(name: str) -> str | None:
    """'lower' / 'higher' = which way is better; None = not a perf metric."""
    for suffix in _LOWER_BETTER:
        if name.endswith(suffix):
            return "lower"
    for mark in _HIGHER_BETTER:
        if mark in name:
            return "higher"
    return None


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    return rows


def jsonable(obj):
    """Recursively convert numpy scalars/arrays so json.dump accepts the
    row dicts benchmarks return."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def write_bench_json(name: str, payload, out_dir: str = "bench-out") -> str:
    """Persist one benchmark's rows as BENCH_<name>.json (the artifact the
    bench-smoke CI lane uploads and compare.py gates against)."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    if isinstance(payload, dict):
        payload.setdefault("schema", BENCH_SCHEMA)
    with open(path, "w") as f:
        json.dump(jsonable(payload), f, indent=2, sort_keys=True)
    return path

"""Shared benchmark helpers: timing, cost analysis, CSV emission."""

from __future__ import annotations

import time

import jax
import numpy as np


def wall_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def normalize_cost(c) -> dict:
    """cost_analysis() returns a dict, a per-device list of dicts, or None
    depending on jax version/backend — normalize to one dict."""
    if isinstance(c, (list, tuple)):
        c = c[0] if c else None
    return c or {}


def cost_of(fn, *args) -> dict:
    """flops / bytes accessed of the jitted fn at these args."""
    c = normalize_cost(jax.jit(fn).lower(*args).compile().cost_analysis())
    return {"flops": float(c.get("flops", 0.0) or 0.0),
            "bytes": float(c.get("bytes accessed", 0.0) or 0.0)}


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    return rows

"""Shared benchmark helpers: timing, cost analysis, CSV + JSON emission."""

from __future__ import annotations

import json
import os
import time

import jax
import numpy as np


def wall_us(fn, *args, iters: int = 5, warmup: int = 2) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def normalize_cost(c) -> dict:
    """cost_analysis() returns a dict, a per-device list of dicts, or None
    depending on jax version/backend — normalize to one dict."""
    if isinstance(c, (list, tuple)):
        c = c[0] if c else None
    return c or {}


def cost_of(fn, *args) -> dict:
    """flops / bytes accessed of the jitted fn at these args."""
    c = normalize_cost(jax.jit(fn).lower(*args).compile().cost_analysis())
    return {"flops": float(c.get("flops", 0.0) or 0.0),
            "bytes": float(c.get("bytes accessed", 0.0) or 0.0)}


def emit(rows, header):
    print(",".join(header))
    for r in rows:
        print(",".join(str(r.get(h, "")) for h in header))
    return rows


def jsonable(obj):
    """Recursively convert numpy scalars/arrays so json.dump accepts the
    row dicts benchmarks return."""
    if isinstance(obj, dict):
        return {str(k): jsonable(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [jsonable(v) for v in obj]
    if isinstance(obj, np.integer):
        return int(obj)
    if isinstance(obj, np.floating):
        return float(obj)
    if isinstance(obj, np.ndarray):
        return obj.tolist()
    return obj


def write_bench_json(name: str, payload, out_dir: str = ".") -> str:
    """Persist one benchmark's rows as BENCH_<name>.json (the artifact the
    bench-smoke CI lane uploads so perf trajectory is recorded per PR)."""
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(jsonable(payload), f, indent=2, sort_keys=True)
    return path

"""Paper Fig. 5 reproduction: relative speedup over int16 conv2d across the
(W, A) precision grid, native (5a, stock-Ara ULPPACK) vs vmacsr (5b, Sparq),
plus the overflow-free region boundary.

The region boundary is exact math (core.packing.k_tile_bound); the paper's
N+M <= 7 LP boundary must fall out (asserted).  Speedups come from the
instruction-count model (per-output vector-issue counts), the same model
whose W2A2/W3A3 points are calibrated against Fig. 4.
"""

from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import packing, vmacsr
from repro.core.packing import PackSpec

K = 7 * 7 * 32   # 7x7 kernel over 32 channels (paper Fig. 5 setting)


def run(quick: bool = False):
    del quick
    rows = []
    for wb in range(1, 5):
        for ab in range(1, 5):
            for mode in ("native", "vmacsr"):
                # pick the densest feasible lane (int8 preferred: 2x lanes)
                spec = None
                for lane in (jnp.int8.dtype, jnp.int16.dtype):
                    cand = PackSpec(wb, ab, lane)
                    if cand.feasible:
                        spec = cand
                        break
                if spec is None:
                    rows.append({"mode": mode, "w_bits": wb, "a_bits": ab,
                                 "lane": "-", "k_tile": 0,
                                 "speedup_vs_int16": "overflow"})
                    continue
                if mode == "native":
                    ic = vmacsr.native_ulppack_instruction_count(
                        K, spec.k_tile, spec.n_pack)
                else:
                    ic = vmacsr.vmacsr_instruction_count(
                        K, spec.k_tile, spec.n_pack)
                width_gain = 2 if spec.lane_dtype == jnp.int8.dtype else 1
                speed = (vmacsr.int16_instruction_count(K).total
                         / ic.total) * width_gain
                rows.append({
                    "mode": mode, "w_bits": wb, "a_bits": ab,
                    "lane": str(jnp.dtype(spec.lane_dtype).name),
                    "k_tile": spec.k_tile,
                    "speedup_vs_int16": round(speed, 2),
                })

    # overflow-region assertions (paper §IV-A): int16 lanes obey N+M<=7
    region = packing.overflow_free_region(jnp.int16.dtype, max_bits=4)
    for (wb, ab), kt in region.items():
        assert (kt >= 1) == (wb + ab <= 7), (wb, ab, kt)
    print("# overflow-free region (int16 lanes) == {N+M<=7}: verified")

    emit(rows, ["mode", "w_bits", "a_bits", "lane", "k_tile",
                "speedup_vs_int16"])
    return rows


if __name__ == "__main__":
    run()

"""Diff two BENCH_*.json result sets — the CI perf-regression gate.

    PYTHONPATH=src python -m benchmarks.compare \
        --baseline reports/BENCH_baseline.json --current bench-out

Matches rows by bench + case identity (benchmarks/common.py record schema)
and compares every recognized perf metric.  **Gated** metrics — same-run
ratios (speedups, slots/shrink factors), which are machine-portable — fail
the gate when they regress beyond ``--tolerance`` (default 25%) or go
missing; absolute wall-clock/throughput metrics are report-only by default
(runners vary; ``--gate-absolute`` arms them too, e.g. for the nightly
same-runner-class trend job).  Exit status: 0 = pass, 1 = regression,
2 = usage/IO error.  A markdown summary goes to stdout and, when the
environment provides it, ``$GITHUB_STEP_SUMMARY`` (DESIGN.md §14).

**Floors**: a bench row may carry ``"floor": {metric: minimum}`` to assert
a hard lower bound on its own same-run ratio, independent of any baseline
(e.g. serve.speculative requires ``speculative_speedup`` > 1.5x, the
DESIGN.md §19 acceptance bar).  Floors are checked against the CURRENT
run's rows — a fresh baseline cannot launder a broken floor away — and a
violation is a gated ``below-floor`` failure even when the delta-vs-
baseline is within tolerance.

Baseline refresh is one command:

    PYTHONPATH=src python -m benchmarks.run --quick --update-baseline
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

from benchmarks.common import BENCH_SCHEMA, metric_direction, row_case

#: Metric-name regexes whose regression fails the gate: same-run ratios
#: (machine-portable — a tuned kernel that stops beating its baseline, a
#: capacity factor that shrinks).  Deterministic model outputs
#: (modeled_speedup, speedup_vs_int16) are covered by the same patterns.
GATED_PATTERNS = (r"speedup", r"_vs_bf16$", r"^tuned_vs_heuristic$")

#: Armed additionally by --gate-absolute (same-machine trend lanes only).
ABSOLUTE_PATTERNS = (r"_us$", r"tok_s$", r"^slots$",
                     r"^cache_bytes_per_slot$")

#: A measured speedup whose baseline sits in this band recorded no
#: material win/loss — the ratio of two near-comparable schedules, whose
#: ordering can flip on runner microarchitecture or load (observed: a
#: 1.32x same-run XLA ratio remeasuring at 0.99x under CPU contention).
#: Such rows are demoted to report-only so CI cannot fail on timing noise;
#: the material wins (1.5x+: tuned tile grids, engine chunking, capacity
#: factors) stay gated.
NEAR_UNITY_BAND = (0.67, 1.5)


def is_gated(metric: str, extra=(), absolute: bool = False) -> bool:
    pats = GATED_PATTERNS + tuple(extra)
    if absolute:
        pats = pats + ABSOLUTE_PATTERNS
    return any(re.search(p, metric) for p in pats)


# ---------------------------------------------------------------------------
# Loading: a merged baseline file, a single BENCH_*.json, or a directory
# ---------------------------------------------------------------------------

def load_payloads(path: str) -> dict:
    """-> {bench key: payload dict with 'rows'} from any supported layout."""
    if os.path.isdir(path):
        out = {}
        for p in sorted(glob.glob(os.path.join(path, "BENCH_*.json"))):
            with open(p) as f:
                payload = json.load(f)
            key = payload.get("bench") or \
                os.path.basename(p)[len("BENCH_"):-len(".json")]
            out[key] = payload
        if not out:
            raise FileNotFoundError(f"no BENCH_*.json under {path}")
        return out
    with open(path) as f:
        data = json.load(f)
    if "benches" in data:          # merged baseline layout
        return data["benches"]
    if "rows" in data:             # a single BENCH_<key>.json
        return {data.get("bench", os.path.basename(path)): data}
    raise ValueError(f"{path}: neither a baseline nor a BENCH json")


def _flatten(payloads: dict) -> dict:
    """-> {'bench' or 'bench.sub': {case: row}} with schema checks."""
    out = {}
    for bench, payload in payloads.items():
        schema = payload.get("schema", BENCH_SCHEMA)
        if schema != BENCH_SCHEMA:
            raise ValueError(f"bench {bench}: schema {schema} != "
                             f"{BENCH_SCHEMA}; refresh the baseline")
        rows = payload.get("rows")
        groups = rows.items() if isinstance(rows, dict) else [(None, rows)]
        for sub, rs in groups:
            key = f"{bench}.{sub}" if sub else bench
            out[key] = {row_case(r, i): r
                        for i, r in enumerate(rs or []) if isinstance(r, dict)}
    return out


# ---------------------------------------------------------------------------
# Comparison
# ---------------------------------------------------------------------------

def _num(v):
    if isinstance(v, bool) or not isinstance(v, (int, float)):
        return None
    return float(v)


def compare(baseline: dict, current: dict, *, tolerance: float = 0.25,
            extra_gates=(), gate_absolute: bool = False) -> list[dict]:
    """-> finding rows: {bench, case, metric, base, cur, delta_pct, gated,
    status in {ok, improved, regressed, missing}} for every compared metric
    (info-only metrics are skipped)."""
    base_f, cur_f = _flatten(baseline), _flatten(current)
    findings = []

    def add(bench, case, metric, base_v, cur_v, gated):
        direction = metric_direction(metric)
        if direction is None:
            return
        b, c = _num(base_v), _num(cur_v)
        if b is None:
            return                      # non-numeric baseline: not gateable
        if c is None:
            findings.append({"bench": bench, "case": case, "metric": metric,
                             "base": b, "cur": None, "delta_pct": None,
                             "gated": gated, "status": "missing"})
            return
        if b == 0:
            delta = 0.0 if c == 0 else float("inf") * (1 if c > b else -1)
        else:
            delta = (c - b) / abs(b)
        worse = -delta if direction == "higher" else delta
        status = "ok"
        if worse > tolerance:
            status = "regressed"
        elif worse < -tolerance:
            status = "improved"
        findings.append({"bench": bench, "case": case, "metric": metric,
                         "base": b, "cur": c,
                         "delta_pct": round(delta * 100, 1),
                         "gated": gated, "status": status})

    for bench, base_rows in sorted(base_f.items()):
        cur_rows = cur_f.get(bench)
        for case, base_row in base_rows.items():
            cur_row = (cur_rows or {}).get(case, {})
            # Lane-layout identity (DESIGN.md §16): rows that record the
            # PackSpec their timings were measured under (``spec``) are only
            # apples-to-apples when both runs chose the same layout.  When
            # the autotuner picked a different layout, surface that as an
            # explicit layout-changed finding and demote the row's ratio
            # metrics to report-only rather than silently gating a
            # cross-layout comparison.
            b_spec, c_spec = base_row.get("spec"), cur_row.get("spec")
            layout_changed = (isinstance(b_spec, str)
                              and isinstance(c_spec, str)
                              and b_spec != c_spec)
            if layout_changed:
                findings.append({"bench": bench, "case": case,
                                 "metric": "spec", "base": b_spec,
                                 "cur": c_spec, "delta_pct": None,
                                 "gated": False,
                                 "status": "layout-changed"})
            for metric, base_v in base_row.items():
                if metric_direction(metric) is None:
                    continue
                gated = is_gated(metric, extra_gates, gate_absolute) \
                    and not layout_changed
                b = _num(base_v)
                if gated and "speedup" in metric and b is not None and \
                        NEAR_UNITY_BAND[0] <= b <= NEAR_UNITY_BAND[1]:
                    gated = False
                add(bench, case, metric, base_v, cur_row.get(metric), gated)
    findings.extend(_floor_findings(cur_f))
    return findings


def _floor_findings(cur_flat: dict) -> list[dict]:
    """Hard same-run minimums (module docstring): every current row with a
    ``floor`` mapping yields one gated finding per floored metric —
    ``below-floor`` when the measured value undercuts the bound (or is
    absent/non-numeric), ``ok`` otherwise.  ``base`` carries the floor so
    the summary table reads 'required vs measured'."""
    findings = []
    for bench, rows in sorted(cur_flat.items()):
        for case, row in rows.items():
            floor = row.get("floor")
            if not isinstance(floor, dict):
                continue
            for metric, bound in sorted(floor.items()):
                bound_v, cur_v = _num(bound), _num(row.get(metric))
                if bound_v is None:
                    continue
                ok = cur_v is not None and cur_v >= bound_v
                findings.append({
                    "bench": bench, "case": case,
                    "metric": f"{metric} (floor)",
                    "base": bound_v, "cur": cur_v, "delta_pct": None,
                    "gated": True,
                    "status": "ok" if ok else "below-floor"})
    return findings


def gate_failures(findings: list[dict]) -> list[dict]:
    return [f for f in findings
            if f["gated"] and f["status"] in ("regressed", "missing",
                                              "below-floor")]


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------

_MARK = {"ok": "✓", "improved": "▲", "regressed": "✗", "missing": "∅",
         "layout-changed": "↻", "below-floor": "✗"}


def _fmt(v) -> str:
    if v is None:
        return "—"
    return f"{v:g}" if isinstance(v, (int, float)) else str(v)


def to_markdown(findings: list[dict], tolerance: float) -> str:
    failures = gate_failures(findings)
    lines = ["# Perf-regression gate",
             "",
             f"**{'FAIL' if failures else 'PASS'}** — "
             f"{len(failures)} gated regression(s) out of "
             f"{sum(1 for f in findings if f['gated'])} gated / "
             f"{len(findings)} compared metrics "
             f"(tolerance ±{tolerance * 100:.0f}%).",
             ""]
    shown = [f for f in findings
             if f["gated"] or f["status"] in ("regressed", "missing",
                                              "improved", "layout-changed")]
    if shown:
        lines += ["| bench | case | metric | base | current | Δ% | gated "
                  "| status |",
                  "|---|---|---|---|---|---|---|---|"]
        for f in shown:
            delta = "—" if f["delta_pct"] is None else f"{f['delta_pct']:+g}"
            lines.append(
                f"| {f['bench']} | {f['case']} | {f['metric']} "
                f"| {_fmt(f['base'])} | {_fmt(f['cur'])} | {delta} "
                f"| {'yes' if f['gated'] else ''} "
                f"| {_MARK[f['status']]} {f['status']} |")
    else:
        lines.append("No perf metrics differed beyond tolerance.")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="diff two BENCH json sets; non-zero exit on gated "
                    "regression")
    ap.add_argument("--baseline", required=True,
                    help="merged baseline json, single BENCH json, or dir")
    ap.add_argument("--current", required=True,
                    help="same layouts as --baseline")
    ap.add_argument("--tolerance", type=float, default=0.25,
                    help="relative regression allowed on gated metrics "
                         "(0.25 = 25%%)")
    ap.add_argument("--gate", action="append", default=[],
                    help="extra metric-name regex to gate (repeatable)")
    ap.add_argument("--gate-absolute", action="store_true",
                    help="also gate absolute wall/throughput metrics "
                         "(same-runner-class lanes only)")
    ap.add_argument("--summary", default="",
                    help="also write the markdown summary to this path")
    args = ap.parse_args(argv)

    try:
        base = load_payloads(args.baseline)
        cur = load_payloads(args.current)
        findings = compare(base, cur, tolerance=args.tolerance,
                           extra_gates=tuple(args.gate),
                           gate_absolute=args.gate_absolute)
    except (OSError, ValueError) as e:
        print(f"compare: {e}", file=sys.stderr)
        return 2

    md = to_markdown(findings, args.tolerance)
    print(md)
    step_summary = os.environ.get("GITHUB_STEP_SUMMARY")
    for path in filter(None, (args.summary, step_summary)):
        with open(path, "a") as f:
            f.write(md)
    failures = gate_failures(findings)
    for f in failures:
        print(f"GATE FAIL: {f['bench']}/{f['case']}/{f['metric']}: "
              f"{f['base']:g} -> "
              f"{'missing' if f['cur'] is None else f['cur']}",
              file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())

"""Serving microbench, three levels (DESIGN.md §12, §13):

* ``run_linear`` — bf16 vs unpacked-int vs packed ULPPACK paths at decode
  shapes, on CPU XLA (wall-clock) + compiled FLOP/byte counts.  The
  LM-integration counterpart of fig4 (which benches the paper's conv2d).
* ``run_engine`` — engine-level before/after: chunked-prefill continuous
  batching (``ServingEngine`` with prefill_chunk > 1) against the
  token-at-a-time admission baseline (prefill_chunk=1) at prompt length
  64, reporting the scheduler Metrics (prefill/decode tokens/s, slot
  occupancy).  This is the end-to-end number the paper's thesis is about:
  kernels only pay off when the serving layer keeps them fed.
* ``run_kv_cache`` — cache-bytes-per-slot + decode tok/s at kv_bits in
  {16, 8, 4, 2} under one fixed HBM cache budget: the sub-byte packed KV
  cache converts bit density into admission capacity (slots scale with the
  bytes shrink), the serving-side analogue of the paper's sub-byte storage
  thesis.
* ``run_paged`` — paged, prefix-sharing KV cache (serve/pages.py,
  DESIGN.md §18) vs the slot-contiguous cache under one fixed HBM budget
  on a 64-token shared-prefix workload: peak concurrent sequences,
  prefix-share ratio, COW/page counters, and token identity.  Report-only
  by metric naming; tests/test_paged_kv.py gates the semantics.
* ``run_sharded`` — tensor-parallel packed engine (serve/shard.ShardPlan,
  DESIGN.md §15) vs the single-device engine on the same requests.
  Report-only (CPU-simulated meshes measure collective overhead, not TP
  scaling; the metric names deliberately avoid the gated speedup/_vs_bf16
  patterns) and degrades to a single row noting the device count when the
  host has one device (force more with
  XLA_FLAGS=--xla_force_host_platform_device_count=4).
* ``run_router`` — replica-fleet Router (serve/router.py, DESIGN.md §17)
  under a saturating request burst: 1 replica vs 2 replicas behind the
  load-balanced front door.  Report-only for the same reason: fleet
  decode tok/s is the SUM of per-replica rates (each replica models
  disjoint hardware; a process-local host shares one box), so
  ``decode_tok_s_ratio_vs_single`` states the fleet-aggregation model
  rather than measuring host speedup — tests/test_router.py gates the
  semantics (identity, spillover, affinity, drain/restore).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import (cost_of, emit, record,
                               tuned_vs_heuristic_row, wall_us)
from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import ops
from repro.kernels import plan as plan_lib


def run_linear(quick: bool = False):
    m = 8                       # decode rows per device
    k, n = (1024, 1024) if quick else (4096, 4096)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    rows = []

    wb16 = w.astype(jnp.bfloat16)

    def bf16(x):
        return jnp.dot(x.astype(jnp.bfloat16), wb16)

    c = cost_of(bf16, x)
    rows.append({"path": "bf16", "wall_us": round(wall_us(bf16, x), 1),
                 **c, "weight_bytes": wb16.size * 2})

    w8 = jnp.clip(jnp.round(w / 0.01), -127, 127).astype(jnp.int8)

    def int8(x):
        q = jnp.clip(jnp.round(x / 0.05), -127, 127).astype(jnp.int8)
        return ops.int_matmul(q, w8, backend="xla")

    c = cost_of(int8, x)
    rows.append({"path": "int8-unpacked", "wall_us": round(wall_us(int8, x),
                                                           1),
                 **c, "weight_bytes": w8.size})

    for wb, ab in ((1, 1), (2, 2), (3, 3)):
        spec = PackSpec(wb, ab, jnp.int16.dtype)
        wp, cs = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(
            1 << (wb - 1)), spec)

        def packed(x, wp=wp, cs=cs, spec=spec, wb=wb):
            return ops.quantized_linear(
                x, wp, cs, jnp.float32(0.07),
                jnp.int32(1 << (ab - 1)), jnp.float32(0.02),
                jnp.int32(1 << (wb - 1)), spec, backend="xla")

        c = cost_of(packed, x)
        rows.append({"path": f"packed-W{wb}A{ab}",
                     "wall_us": round(wall_us(packed, x), 1), **c,
                     "weight_bytes": wp.size * 2})

    emit(rows, ["path", "wall_us", "flops", "bytes", "weight_bytes"])
    rows += _tuned_vs_heuristic_linear()
    return rows


#: The decode-shaped linear the tuned-vs-heuristic row (and run.warm_tune)
#: benchmarks through the Pallas tile grid.
TUNED_LINEAR_SHAPE = (8, 256, 256)

# (b, skv, h, kvh, hd, kv_bits, page_size) of the gated attention-decode
# headline row; benchmarks/run.py warm-tunes exactly this signature so the
# committed autotune cache can never desync from the gate (DESIGN.md §20)
ATTN_DECODE_SHAPE = (4, 2048, 8, 4, 64, 2, 16)


def _tuned_vs_heuristic_linear():
    """Decode-shaped Pallas packed matmul under the autotuned plan vs the
    static heuristic (the fused-kernel tile grid is where the autotuner's
    wins live; the XLA rows above ignore tile choice).  Cache miss ->
    tuned == heuristic, speedup 1.0 (DESIGN.md §14)."""
    m, k, n = TUNED_LINEAR_SHAPE
    spec = PackSpec(2, 2, jnp.int16.dtype)
    rng = np.random.default_rng(1)
    q_a = jnp.asarray(rng.integers(0, spec.max_a + 1, (m, k)), jnp.int32)
    q_w = jnp.asarray(rng.integers(0, spec.max_w + 1, (k, n)), jnp.int32)
    ap = packing.pack_activations(q_a, spec, axis=-1)
    wp = packing.pack_weights(q_w, spec, axis=0)
    kp = ap.shape[-1]
    heur = plan_lib.plan_packed_matmul(m, kp, n, spec, backend="pallas",
                                       use_tuning_cache=False)
    tuned = plan_lib.plan_packed_matmul(m, kp, n, spec, backend="pallas")
    rows = [tuned_vs_heuristic_row(
        "tuned-vs-heuristic/packed-W2A2", heur, tuned,
        lambda plan: ops.packed_matmul(ap, wp, spec, plan=plan))]
    emit(rows, ["case", "heuristic_us", "tuned_us", "tuned_speedup",
                "plan_source", "plan"])
    rows += _layout_sweep_linear()
    return rows


def _layout_sweep_linear():
    """Chosen lane layout vs the fixed-layout heuristic at the decode
    linear shape (W2A2, lanes store), through the same Pallas dispatch —
    the matmul counterpart of fig4's layout-sweep row.

    The candidate layout comes from the committed layout cache
    (autotune.matmul_layout_for; warm-tuned by ``benchmarks.run
    --autotune``); each side packs its own operands, since the layout is
    the offline packing decision this axis tunes.  On a layout-cache miss
    the chosen spec IS the config default (speedup 1.0).  The chosen
    layout's output is asserted bit-exact against the unpacked int32
    reference before it is timed (DESIGN.md §16)."""
    from repro.kernels import autotune, ref

    m, k, n = TUNED_LINEAR_SHAPE
    base = PackSpec(2, 2, jnp.int16.dtype)
    rng = np.random.default_rng(2)
    q_a = jnp.asarray(rng.integers(0, base.max_a + 1, (m, k)), jnp.int32)
    q_w = jnp.asarray(rng.integers(0, base.max_w + 1, (k, n)), jnp.int32)
    want = np.asarray(ref.matmul_i32_ref(q_a, q_w))
    chosen = autotune.matmul_layout_for(k, n, base, backend="pallas",
                                        weight_store="lanes")

    def operands(spec):
        return (packing.pack_activations(q_a, spec, axis=-1),
                packing.pack_weights(q_w, spec, axis=0))

    ab, wb = operands(base)
    heur = plan_lib.plan_packed_matmul(m, ab.shape[-1], n, base,
                                       backend="pallas",
                                       use_tuning_cache=False)
    heur_us = wall_us(lambda: ops.packed_matmul(ab, wb, base, plan=heur),
                      iters=1, warmup=1)
    ac, wc = operands(chosen)
    tuned = plan_lib.plan_packed_matmul(m, ac.shape[-1], n, chosen,
                                        backend="pallas")
    got = ops.packed_matmul(ac, wc, chosen, plan=tuned)
    np.testing.assert_array_equal(np.asarray(got), want)
    tuned_us = heur_us if (chosen, tuned) == (base, heur) else wall_us(
        lambda: ops.packed_matmul(ac, wc, chosen, plan=tuned),
        iters=1, warmup=1)
    rows = [record("layout-sweep/linear",
                   heuristic_us=round(heur_us, 1),
                   tuned_us=round(tuned_us, 1),
                   tuned_speedup=round(heur_us / tuned_us, 2),
                   spec=str(chosen), base_spec=str(base),
                   plan_source=tuned.source, plan=str(tuned))]
    emit(rows, ["case", "heuristic_us", "tuned_us", "tuned_speedup",
                "spec", "base_spec", "plan_source", "plan"])
    return rows


PROMPT_LEN = 64


def run_engine(quick: bool = False):
    """Engine-level prefill/decode throughput: chunked prefill vs the
    token-at-a-time baseline (chunk=1) at prompt length 64."""
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Request, ServingEngine

    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 2 if quick else 4
    max_batch = 2
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, PROMPT_LEN).astype(np.int32)
               for _ in range(n_req)]

    def bench(chunk):
        from repro.serve.engine import Metrics
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_batch=max_batch, max_len=PROMPT_LEN + 16, packed=False,
            prefill_chunk=chunk))
        # warmup: compile both jitted steps outside the measured window
        eng.submit(Request(uid=10_000, prompt=prompts[0],
                           max_new_tokens=4))
        eng.run_to_completion()
        eng.metrics = Metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=4))
        eng.run_to_completion()
        return eng.metrics.report()

    chunks = (1, 16) if quick else (1, 8, 16, 32)
    rows = []
    base = None
    for chunk in chunks:
        rep = bench(chunk)
        if chunk == 1:
            base = rep["prefill_tok_s"]
        rows.append({
            "engine": "token-at-a-time" if chunk == 1
            else f"chunked-prefill-{chunk}",
            "prefill_chunk": chunk,
            "prompt_len": PROMPT_LEN,
            "prefill_tok_s": rep["prefill_tok_s"],
            "decode_tok_s": rep["decode_tok_s"],
            "occupancy": rep["occupancy"],
            "steps": rep["steps"],
            "speedup_vs_baseline": round(rep["prefill_tok_s"] / base, 2)
            if base else 0.0,
        })
    emit(rows, ["engine", "prefill_chunk", "prompt_len", "prefill_tok_s",
                "decode_tok_s", "occupancy", "steps",
                "speedup_vs_baseline"])
    return rows


def run_kv_cache(quick: bool = False):
    """Cache bytes/slot + decode tok/s vs kv_bits under one HBM budget.

    The budget is fixed at ``base_slots`` bf16 slots; quantized caches admit
    budget // bytes-per-slot concurrent sequences, so the slots column shows
    the admission-capacity win (~2x int8, ~4x 4-bit, ~8x 2-bit) alongside
    the decode throughput of each storage layout.  head_dim=64 matches the
    full model (the reduced config's derived 16 would understate density:
    per-(pos, head) scales amortize over the head dim).
    """
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Metrics, Request, ServingEngine
    from repro.serve.prepare import cache_bytes_per_slot

    base = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", head_dim=64,
        quant=QuantConfig(enabled=False))
    params = lm.init_params(jax.random.PRNGKey(0), base)
    max_len = 48
    base_slots = 2 if quick else 4
    budget = base_slots * cache_bytes_per_slot(base, max_len)
    prompt_len, new_tokens = 8, 4 if quick else 8
    rng = np.random.default_rng(0)

    rows = []
    ref = None
    for kv_bits in (16, 8, 4, 2):
        cfg = base.replace(quant=QuantConfig(
            enabled=False, kv_bits=0 if kv_bits == 16 else kv_bits))
        eng = ServingEngine(cfg, params, config=EngineConfig(
            max_len=max_len, packed=False, prefill_chunk=8,
            hbm_cache_budget=budget))
        n_req = eng.max_batch
        prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(
            np.int32) for _ in range(n_req)]
        # warmup compiles both jitted steps outside the measured window
        eng.submit(Request(uid=10_000, prompt=prompts[0], max_new_tokens=2))
        eng.run_to_completion()
        eng.metrics = Metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p,
                               max_new_tokens=new_tokens))
        eng.run_to_completion()
        rep = eng.metrics.report()
        cap = eng.capacity_report()
        if kv_bits == 16:
            ref = cap
        rows.append({
            "kv_bits": kv_bits,
            "cache_bytes_per_slot": cap["cache_bytes_per_slot"],
            "slots": cap["slots"],
            "decode_tok_s": rep["decode_tok_s"],
            "shrink_vs_bf16": round(ref["cache_bytes_per_slot"]
                                    / cap["cache_bytes_per_slot"], 2),
            "slots_vs_bf16": round(cap["slots"] / ref["slots"], 2),
        })
    emit(rows, ["kv_bits", "cache_bytes_per_slot", "slots", "decode_tok_s",
                "shrink_vs_bf16", "slots_vs_bf16"])
    return rows


def run_attention_decode(quick: bool = False):
    """Fused flash-decoding attention read vs the legacy decode path
    (kernels/ulppack_attention.py, DESIGN.md §20), same-run.

    The legacy path gathers the paged cache into its logical [B, S] view
    (unpaged: dequantizes the ring) and softmaxes one [B, H, S] score
    row; the fused read walks the stored cache in online-softmax groups
    and skips groups past the live high-water mark — so at serving
    shapes (2048-token allocation, ~520 live) it pays O(live) where the
    legacy path pays O(allocated).  ``attention_decode_speedup`` is a
    same-run ratio at the paged sub-byte headline shape and carries a
    hard floor; tests/test_fused_attention.py gates the numerics.  The
    long-context ENGINE case (512-token prompts, kv_bits=2, paged) is
    report-only: end-to-end decode tok/s where the fused read dominates
    the step.
    """
    from repro.kernels import ulppack_attention as ua
    from repro.models import attention as attn

    b, skv, h, kvh, hd, kv_bits, ps = ATTN_DECODE_SHAPE
    n_pages = skv // ps                   # 2048-token logical view
    size = skv
    live = 520
    rng = np.random.default_rng(0)

    def quantized(shape_rows):
        k = jnp.asarray(rng.normal(size=(shape_rows, ps, kvh, hd)),
                        jnp.float32)
        v = jnp.asarray(rng.normal(size=(shape_rows, ps, kvh, hd)),
                        jnp.float32)
        qk, sk = attn._kv_quantize(k, kv_bits)
        qv, sv = attn._kv_quantize(v, kv_bits)
        return {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}

    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    vl = jnp.full((b,), live, jnp.int32)
    qpos = jnp.full((b, 1), live - 1, jnp.int32)

    def legacy(cache, bt):
        def fn(q, cache, vl, qpos):
            if bt is None:
                k, v = attn._cache_read(cache, jnp.float32, kv_bits, hd)
            else:
                k, v = attn._paged_cache_read(cache, bt, jnp.float32,
                                              kv_bits, hd)
            kv_pos = attn._ring_positions_batch(vl - 1, size, 0)
            mask = (kv_pos[:, None, :] <= qpos[:, :, None]) \
                & (kv_pos[:, None, :] >= 0)
            return attn._chunked_attention(
                q, lambda: (k, v), lambda _: mask, qpos, 1)
        return jax.jit(fn)

    def fused(bt):
        def fn(q, cache, vl, qpos):
            return ua.fused_decode_attention(q, cache, vl, qpos,
                                             kv_bits=kv_bits, hd=hd,
                                             block_tables=bt,
                                             backend="xla")
        return jax.jit(fn)

    rows = []
    for case, paged in (("attention-decode/paged-kv2", True),
                        ("attention-decode/contiguous-kv2", False)):
        if paged:
            cache = quantized(b * n_pages)
            bt = jnp.asarray(np.arange(b * n_pages).reshape(b, n_pages),
                             jnp.int32)
        else:
            pool = quantized(b * n_pages)
            cache = {kk: vv.reshape(b, size, *vv.shape[2:])
                     for kk, vv in pool.items()}
            bt = None
        old_fn, new_fn = legacy(cache, bt), fused(bt)
        diff = float(jnp.max(jnp.abs(new_fn(q, cache, vl, qpos)
                                     - old_fn(q, cache, vl, qpos))))
        old_us = wall_us(old_fn, q, cache, vl, qpos)
        new_us = wall_us(new_fn, q, cache, vl, qpos)
        row = {
            "case": case, "kv_bits": kv_bits, "alloc_tokens": size,
            "live_tokens": live, "page_size": ps if paged else 0,
            "legacy_us": round(old_us, 1), "fused_us": round(new_us, 1),
            "attention_decode_speedup": round(old_us / max(new_us, 1e-9),
                                              2),
            "max_abs_diff": round(diff, 7),
        }
        if paged:
            row["floor"] = {"attention_decode_speedup": 1.3}
        rows.append(row)

    # long-context engine case: report-only end-to-end tok/s
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Metrics, Request, ServingEngine

    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", head_dim=64,
        quant=QuantConfig(enabled=False, kv_bits=2))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    prompt_len, new_tokens = 512, 4 if quick else 8
    eng = ServingEngine(cfg, params, config=EngineConfig(
        max_batch=2, max_len=prompt_len + new_tokens + 2, packed=False,
        prefill_chunk=64, paged=True, page_size=ps))
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len)
               .astype(np.int32) for _ in range(2)]
    eng.submit(Request(uid=10_000, prompt=prompts[0], max_new_tokens=2))
    eng.run_to_completion()
    eng.metrics = Metrics()
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
    eng.run_to_completion()
    rep = eng.metrics.report()
    rows.append({
        "case": "attention-decode/long-context-engine",
        "kv_bits": 2, "alloc_tokens": prompt_len + new_tokens + 2,
        "live_tokens": prompt_len, "page_size": ps,
        "prompt_len": prompt_len,
        "decode_tok_s": rep["decode_tok_s"],
        "prefill_tok_s": rep["prefill_tok_s"],
    })
    emit(rows, ["case", "kv_bits", "alloc_tokens", "live_tokens",
                "legacy_us", "fused_us", "attention_decode_speedup",
                "decode_tok_s"])
    return rows


def run_paged(quick: bool = False):
    """Paged, prefix-sharing KV cache vs the slot-contiguous cache under
    ONE fixed HBM budget on a shared-prefix workload (DESIGN.md §18).

    Every request shares a 64-token prefix and adds a short unique tail —
    the system-prompt shape paging exists for.  The unpaged engine sizes
    whole ``max_len`` slots from the budget (4 here); the paged engine
    spends the same bytes on a 4-bit page pool, primes the prefix cache
    with one warmup request, then admits every follow-up at ~2 fresh
    pages apiece — ``peak_live_slot_count`` / ``logical_slot_multiplier``
    show concurrent sequences at >= 2x the unpaged slot count, and
    ``prompt_rows_computed`` shows the prefill work the radix cache
    skipped.  Report-only by metric naming (counters and ratios carry no
    gated suffix); tests/test_paged_kv.py gates the token-identity and
    capacity semantics.
    """
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Metrics, Request, ServingEngine
    from repro.serve.prepare import cache_bytes_per_slot

    base = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32", head_dim=64,
        quant=QuantConfig(enabled=False, kv_bits=4))
    params = lm.init_params(jax.random.PRNGKey(0), base)
    page_size, max_len = 16, 80
    budget = 4 * cache_bytes_per_slot(base, max_len)
    n_req, new_tokens = 12, 2 if quick else 4
    tail_len = 4
    rng = np.random.default_rng(0)
    prefix = rng.integers(0, base.vocab_size, PROMPT_LEN).astype(np.int32)
    prompts = [np.concatenate([prefix, rng.integers(
        0, base.vocab_size, tail_len).astype(np.int32)])
        for _ in range(n_req)]

    def bench(econf, warm_prefix):
        eng = ServingEngine(base, params, config=econf)
        # warmup compiles both steps; for the paged engine it also primes
        # the radix prefix cache (a system prompt being cached once)
        eng.submit(Request(uid=10_000,
                           prompt=prefix if warm_prefix else prompts[0],
                           max_new_tokens=2))
        eng.run_to_completion()
        eng.metrics = Metrics()
        if warm_prefix:
            eng.peak_live_slots = 0
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
        outs = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
        return eng.metrics.report(), eng.capacity_report(), outs

    unpaged_rep, unpaged_cap, unpaged_out = bench(
        EngineConfig(max_len=max_len, packed=False, prefill_chunk=16,
                     hbm_cache_budget=budget), warm_prefix=False)
    paged_rep, paged_cap, paged_out = bench(
        EngineConfig(max_batch=16, max_len=max_len, packed=False,
                     prefill_chunk=16, hbm_cache_budget=budget,
                     paged=True, page_size=page_size), warm_prefix=True)

    total_prompt = sum(len(p) for p in prompts)
    rows = [{
        "case": "kv-paged/unpaged",
        "kv_bits": 4, "requests": n_req, "shared_prefix_len": PROMPT_LEN,
        "logical_slot_capacity": unpaged_cap["slots"],
        "peak_live_slot_count": unpaged_cap["slots"],
        "logical_slot_multiplier": 1.0,
        "prompt_rows_computed": unpaged_rep["prefill_tokens"],
        "prefix_share_ratio": 0.0,
        "tokens_match": True,
    }, {
        "case": "kv-paged/paged",
        "kv_bits": 4, "requests": n_req, "shared_prefix_len": PROMPT_LEN,
        "logical_slot_capacity": paged_cap["slots"],
        "peak_live_slot_count": paged_cap["peak_live_slot_count"],
        "logical_slot_multiplier": round(
            paged_cap["peak_live_slot_count"] / unpaged_cap["slots"], 2),
        "prompt_rows_computed": paged_rep["prefill_tokens"],
        "prefix_share_ratio": round(
            paged_cap["prefix_hit_tokens"] / total_prompt, 3),
        "tokens_match": paged_out == unpaged_out,
        "num_pages": paged_cap["num_pages"],
        "bytes_per_page": paged_cap["page_bytes"],
        "cached_prefix_pages": paged_cap["cached_prefix_pages"],
        "cow_copies": paged_cap["cow_copies"],
    }]
    emit(rows, ["case", "kv_bits", "requests", "shared_prefix_len",
                "logical_slot_capacity", "peak_live_slot_count",
                "logical_slot_multiplier", "prompt_rows_computed",
                "prefix_share_ratio", "tokens_match"])
    return rows


def run_sharded(quick: bool = False):
    """Sharded-vs-single-device packed engine throughput (report-only).

    Both engines serve the same seeded requests through the packed path
    (w2a2, kv_bits=4); the sharded one on a ('data'=1, 'model'=N) mesh
    over every host device.  ``tokens_match`` records the tentpole
    invariant (token-for-token identical output, tests/test_shard_serving
    gates it); ``decode_tok_s_ratio_vs_single`` is informational.
    """
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.launch.mesh import make_serving_mesh
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Metrics, Request, ServingEngine

    n_dev = len(jax.devices())
    if n_dev < 2:
        # no comparison possible — skip the engine build/compile entirely
        # (run_engine already measures single-device throughput) and leave
        # a note row so the BENCH json says why the comparison is absent
        rows = [{"engine": "single-device", "devices": 1,
                 "note": ("host has 1 device; force a mesh with XLA_FLAGS="
                          "--xla_force_host_platform_device_count=4")}]
        emit(rows, ["engine", "devices", "note"])
        return rows
    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2, kv_bits=4))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    n_req = 2 if quick else 4
    prompt_len, new_tokens = 8, 4 if quick else 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]

    def bench(mesh):
        eng = ServingEngine(cfg, params, mesh=mesh, config=EngineConfig(
            max_batch=2, max_len=32, packed=True, prefill_chunk=8))
        eng.submit(Request(uid=10_000, prompt=prompts[0],
                           max_new_tokens=2))      # warmup: compile steps
        eng.run_to_completion()
        eng.metrics = Metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
        outs = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
        return eng.metrics.report(), outs

    single_rep, single_out = bench(None)
    shard_rep, shard_out = bench(make_serving_mesh(n_dev))
    rows = [{"engine": "single-device", "devices": 1,
             "prefill_tok_s": single_rep["prefill_tok_s"],
             "decode_tok_s": single_rep["decode_tok_s"],
             "decode_tok_s_ratio_vs_single": 1.0, "tokens_match": True},
            {"engine": f"model-parallel-{n_dev}", "devices": n_dev,
             "prefill_tok_s": shard_rep["prefill_tok_s"],
             "decode_tok_s": shard_rep["decode_tok_s"],
             "decode_tok_s_ratio_vs_single": round(
                 shard_rep["decode_tok_s"]
                 / max(single_rep["decode_tok_s"], 1e-9), 3),
             "tokens_match": shard_out == single_out}]
    emit(rows, ["engine", "devices", "prefill_tok_s", "decode_tok_s",
                "decode_tok_s_ratio_vs_single", "tokens_match"])
    return rows


def run_router(quick: bool = False):
    """Replica-fleet saturation: Router(replicas=1) vs Router(replicas=2)
    over the same seeded burst (report-only; module docstring caveat).

    The burst oversubscribes each replica's bounded queue so the fleet
    spillover engages; the 2-replica row shows the spill falling and the
    aggregated decode rate roughly doubling by construction of the fleet
    metric (summed per-replica rates; DESIGN.md §17).
    """
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.router import Router

    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=False))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    econf = EngineConfig(max_batch=2, max_len=32, packed=False,
                         prefill_chunk=8, max_queue=2)
    n_req = 4 if quick else 8
    prompt_len, new_tokens = 8, 4 if quick else 8
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]

    def bench(replicas):
        router = Router(cfg, params, config=econf, replicas=replicas)
        router.submit(prompts[0], max_new_tokens=2)     # warmup: compile
        router.run_to_completion()
        router.reset_metrics()
        for p in prompts:
            router.submit(p, max_new_tokens=new_tokens)
        router.run_to_completion()
        return router.metrics_report()["fleet"]

    single = bench(1)
    fleet = bench(2)
    rows = []
    for rep in (single, fleet):
        rows.append({
            "case": f"router/replicas-{rep['replicas']}",
            "replicas": rep["replicas"],
            "requests": n_req,
            "decode_tok_s": rep["decode_tok_s"],
            "ttft_p95_s": rep["ttft_s"]["p95"],
            "spilled": rep["spilled"],
            "decode_tok_s_ratio_vs_single": round(
                rep["decode_tok_s"]
                / max(single["decode_tok_s"], 1e-9), 3),
        })
    emit(rows, ["case", "replicas", "requests", "decode_tok_s",
                "ttft_p95_s", "spilled", "decode_tok_s_ratio_vs_single"])
    return rows


def run_speculative(quick: bool = False):
    """Speculative decoding vs plain decode, same run (DESIGN.md §19).

    Two rows on the float engine at a decode-heavy workload (short
    prompts, long generations): plain token-at-a-time decode, and the
    draft-k + verify-in-one-call loop.  The draft here IS the target
    (float params), so greedy acceptance is 1.0 and the measured
    ``speculative_speedup`` isolates the loop's structural win — 2
    launches per committed-window cycle instead of one launch per token
    — at a verified-identical output (``tokens_match``).  The row
    carries ``floor: {speculative_speedup: 1.5}``, the hard same-run
    acceptance bar compare.py enforces on every current run.

    A third, report-only row packs the target at W2A2 and drafts through
    the re-packed sub-byte draft tree (serve/speculative.DraftModel) —
    the full draft-repack path under the real packed kernels, with
    ``acceptance_rate`` showing the draft's fidelity.
    """
    from repro import configs
    from repro.core.quant import QuantConfig
    from repro.models import lm
    from repro.serve.config import EngineConfig
    from repro.serve.engine import Metrics, Request, ServingEngine

    k = 8
    prompt_len = 8
    # 1 prefill-pass token + a whole number of full (k+1)-token cycles,
    # so every measured cycle runs at full draft depth
    new_tokens = 1 + (3 if quick else 6) * (k + 1)
    n_req = 2
    base = configs.get_config("stablelm-1.6b", reduced=True).replace(
        param_dtype="float32", compute_dtype="float32")
    float_cfg = base.replace(quant=QuantConfig(enabled=False))
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, base.vocab_size, prompt_len).astype(np.int32)
               for _ in range(n_req)]

    def bench(cfg, spec_k, packed):
        eng = ServingEngine(cfg, lm.init_params(jax.random.PRNGKey(0), cfg),
                            config=EngineConfig(
            max_batch=n_req, max_len=prompt_len + new_tokens + 2,
            packed=packed, prefill_chunk=8, speculative_k=spec_k))
        # warmup: compile prefill + decode (or draft + verify) steps
        eng.submit(Request(uid=10_000, prompt=prompts[0],
                           max_new_tokens=spec_k + 2))
        eng.run_to_completion()
        eng.metrics = Metrics()
        for i, p in enumerate(prompts):
            eng.submit(Request(uid=i, prompt=p, max_new_tokens=new_tokens))
        outs = {r.uid: tuple(r.output) for r in eng.run_to_completion()}
        return eng.metrics.report(), outs

    plain_rep, plain_out = bench(float_cfg, 0, packed=False)
    spec_rep, spec_out = bench(float_cfg, k, packed=False)
    rows = [{
        "case": "speculative/plain-decode",
        "speculative_k": 0, "new_tokens": new_tokens,
        "decode_tok_s": plain_rep["decode_tok_s"],
    }, {
        "case": "speculative/draft-verify",
        "speculative_k": k, "new_tokens": new_tokens,
        "decode_tok_s": spec_rep["decode_tok_s"],
        "acceptance_rate": spec_rep["acceptance_rate"],
        "spec_cycles": spec_rep["spec_cycles"],
        "speculative_speedup": round(
            spec_rep["decode_tok_s"]
            / max(plain_rep["decode_tok_s"], 1e-9), 2),
        "tokens_match": spec_out == plain_out,
        "floor": {"speculative_speedup": 1.5},
    }]
    packed_plain_rep, _ = bench(base, 0, packed=True)
    packed_rep, _ = bench(base, k, packed=True)
    rows.append({
        "case": "speculative/packed-w2-draft",
        "speculative_k": k, "new_tokens": new_tokens,
        "draft_w_bits": base.quant.w_bits,
        "decode_tok_s": packed_rep["decode_tok_s"],
        "acceptance_rate": packed_rep["acceptance_rate"],
        "spec_cycles": packed_rep["spec_cycles"],
        "decode_tok_s_ratio_vs_plain": round(
            packed_rep["decode_tok_s"]
            / max(packed_plain_rep["decode_tok_s"], 1e-9), 3),
    })
    emit(rows, ["case", "speculative_k", "new_tokens", "decode_tok_s",
                "acceptance_rate", "spec_cycles", "speculative_speedup",
                "tokens_match"])
    return rows


def run(quick: bool = False):
    return {"linear": run_linear(quick),
            "engine": run_engine(quick),
            "kv_cache": run_kv_cache(quick),
            "attention_decode": run_attention_decode(quick),
            "paged": run_paged(quick),
            "sharded": run_sharded(quick),
            "router": run_router(quick),
            "speculative": run_speculative(quick)}


if __name__ == "__main__":
    run()

"""Serving-linear microbench: bf16 vs unpacked-int vs packed ULPPACK paths
at decode shapes, on CPU XLA (wall-clock) + compiled FLOP/byte counts.

This is the LM-integration counterpart of fig4 (which benches the paper's
conv2d): the same packed arithmetic applied to a transformer projection.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import cost_of, emit, wall_us
from repro.core.packing import PackSpec
from repro.kernels import ops


def run(quick: bool = False):
    m = 8                       # decode rows per device
    k, n = (1024, 1024) if quick else (4096, 4096)
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(k, n)) * 0.05, jnp.float32)
    rows = []

    wb16 = w.astype(jnp.bfloat16)

    def bf16(x):
        return jnp.dot(x.astype(jnp.bfloat16), wb16)

    c = cost_of(bf16, x)
    rows.append({"path": "bf16", "wall_us": round(wall_us(bf16, x), 1),
                 **c, "weight_bytes": wb16.size * 2})

    w8 = jnp.clip(jnp.round(w / 0.01), -127, 127).astype(jnp.int8)

    def int8(x):
        q = jnp.clip(jnp.round(x / 0.05), -127, 127).astype(jnp.int8)
        return ops.int_matmul(q, w8, backend="xla")

    c = cost_of(int8, x)
    rows.append({"path": "int8-unpacked", "wall_us": round(wall_us(int8, x),
                                                           1),
                 **c, "weight_bytes": w8.size})

    for wb, ab in ((1, 1), (2, 2), (3, 3)):
        spec = PackSpec(wb, ab, jnp.int16.dtype)
        wp, cs = ops.prepare_weights(w, jnp.float32(0.02), jnp.int32(
            1 << (wb - 1)), spec)

        def packed(x, wp=wp, cs=cs, spec=spec, wb=wb):
            return ops.quantized_linear(
                x, wp, cs, jnp.float32(0.07),
                jnp.int32(1 << (ab - 1)), jnp.float32(0.02),
                jnp.int32(1 << (wb - 1)), spec, backend="xla")

        c = cost_of(packed, x)
        rows.append({"path": f"packed-W{wb}A{ab}",
                     "wall_us": round(wall_us(packed, x), 1), **c,
                     "weight_bytes": wp.size * 2})

    emit(rows, ["path", "wall_us", "flops", "bytes", "weight_bytes"])
    return rows


if __name__ == "__main__":
    run()

"""Quickstart: the paper's technique end-to-end in 60 seconds on CPU.

1. Build a sub-byte packed linear layer (W2A2, int16 lanes).
2. Validate the packed integer path against the float oracle.
3. Run the fused Pallas kernel (interpret mode) and check exactness.
4. Show the overflow-free region (paper Fig. 5 boundary).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.packing import PackSpec, overflow_free_region
from repro.kernels import ops, ref
from repro.kernels.ulppack_matmul import ulppack_matmul

rng = np.random.default_rng(0)

# --- 1. a quantized linear: offline weight packing, runtime act packing ---
spec = PackSpec(w_bits=2, a_bits=2, lane_dtype=jnp.int16.dtype)
print(f"packing spec: {spec}  (k_tile={spec.k_tile} packed lanes between "
      "extractions)")

x = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
w = jnp.asarray(rng.normal(size=(256, 64)) * 0.1, jnp.float32)
w_scale, w_zp = jnp.float32(0.02), jnp.int32(2)
a_scale, a_zp = jnp.float32(0.08), jnp.int32(2)

w_packed, col_sums = ops.prepare_weights(w, w_scale, w_zp, spec)
print(f"weights: {w.shape} f32 -> packed lanes {w_packed.shape} "
      f"{w_packed.dtype} ({w_packed.size * 2} bytes vs {w.size * 4})")

y = ops.quantized_linear(x, w_packed, col_sums, a_scale, a_zp, w_scale,
                         w_zp, spec, backend="xla")
y_ref = ref.quantized_linear_ref(x, w, a_scale, a_zp, w_scale, w_zp,
                                 spec.a_bits, spec.w_bits)
print("packed vs float-oracle max err:",
      float(jnp.max(jnp.abs(y - y_ref))))

# --- 2. the fused Pallas kernel (vmacsr analogue), interpret mode ---
q_a = jnp.asarray(rng.integers(0, 4, (8, 200)), jnp.int32)
q_w = jnp.asarray(rng.integers(0, 4, (200, 16)), jnp.int32)
ap = packing.pack_activations(q_a, spec, -1)
wp = packing.pack_weights(q_w, spec, 0)
got = ulppack_matmul(ap, wp, spec, block_m=8, block_n=8, chunks=2,
                     interpret=True)
want = ref.matmul_i32_ref(q_a, q_w)
assert jnp.array_equal(got, want), "kernel mismatch!"
print("Pallas ulppack_matmul (interpret): EXACT match with integer oracle")

# --- 3. the overflow-free region (paper Fig. 5 / N+M<=7) ---
print("\noverflow-free k_tile table, int16 lanes (0 = unusable):")
region = overflow_free_region(jnp.int16.dtype, max_bits=4)
print("      A=1  A=2  A=3  A=4")
for wb in range(1, 5):
    row = [f"{region[(wb, ab)]:4d}" for ab in range(1, 5)]
    print(f"W={wb} " + " ".join(row))
print("(reproduces the paper's N+M<=7 boundary: W4A4 is 0)")

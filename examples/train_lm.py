"""End-to-end driver: QAT-train a ~100M-parameter LM for a few hundred steps
on CPU with the full production substrate (data pipeline, AdamW + cosine,
checkpointing, fault-tolerant loop).

The model is a scaled-down stablelm-family config (~100M params) trained on
the synthetic motif stream; loss drops visibly within a few hundred steps.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300] [--d-model N]
"""

import argparse

from repro import configs
from repro.core.quant import QuantConfig
from repro.configs.base import ParallelConfig
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainLoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--qat", action="store_true", default=True)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = configs.get_config("stablelm-1.6b").replace(
        num_layers=args.layers, d_model=args.d_model,
        num_heads=8, num_kv_heads=8, d_ff=args.d_model * 3,
        vocab_size=8192, param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=args.qat, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="none", microbatches=1))
    n_params = cfg.param_counts()["total"]
    print(f"model: {cfg.num_layers}L d={cfg.d_model} "
          f"~{n_params/1e6:.0f}M params, QAT W2A2={cfg.quant.enabled}")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch, seed=0)
    loop = TrainLoopConfig(total_steps=args.steps, checkpoint_every=100,
                           checkpoint_dir=args.ckpt_dir, log_every=10)
    trainer = Trainer(cfg, loop, data_cfg, seed=0,
                      train_step_kwargs={"peak_lr": 1e-3,
                                         "warmup_steps": 30,
                                         "total_steps": args.steps})
    trainer.install_preemption_handler()
    state, step = trainer.run()
    first = trainer.metrics_log[0]["loss"]
    last = trainer.metrics_log[-1]["loss"]
    print(f"\nloss: {first:.3f} -> {last:.3f} over {step} steps "
          f"({'improved' if last < first else 'NO IMPROVEMENT'})")


if __name__ == "__main__":
    main()

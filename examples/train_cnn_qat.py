"""Paper-faithful scenario: QAT-train the sparq-cnn, then deploy through the
packed conv2d path and compare accuracy float vs QAT vs packed-integer —
the software half of the paper's workflow (§III).

Synthetic 10-class problem: each class is a fixed random 'template' image +
noise; a 3-conv network separates them easily, and sub-byte quantization
(W2A2) should retain accuracy (paper §II-A claims minimal degradation).

Run:  PYTHONPATH=src python examples/train_cnn_qat.py [--steps 150]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import cnn
from repro.optim import adamw


def make_data(rng, templates, cfg, n):
    ys = rng.integers(0, cfg.cnn_num_classes, n)
    xs = templates[ys] + 0.4 * rng.normal(size=(n,) + templates.shape[1:])
    return jnp.asarray(xs, jnp.float32), jnp.asarray(ys, jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=400)
    args = ap.parse_args()

    cfg = configs.get_config("sparq-cnn", reduced=True)
    rng = np.random.default_rng(0)
    params = cnn.init_params(jax.random.PRNGKey(0), cfg)
    templates = rng.normal(size=(cfg.cnn_num_classes, 24, 24, 3))
    xs, ys = make_data(rng, templates, cfg, 256)
    xt, yt = make_data(rng, templates, cfg, 128)

    def loss_fn(p, x, y, mode):
        logits = cnn.forward(p, cfg, x, quant_mode=mode, backend="xla")
        logp = jax.nn.log_softmax(logits)
        return -jnp.mean(jnp.take_along_axis(logp, y[:, None], 1))

    opt_cfg = adamw.AdamWConfig(weight_decay=0.0)
    opt = adamw.init(params, opt_cfg)
    step = jax.jit(lambda p, o, x, y: _step(p, o, x, y))

    def _step(p, o, x, y):
        l, g = jax.value_and_grad(lambda q: loss_fn(q, x, y, "qat"))(p)
        upd, o = adamw.update(g, o, p, 1e-2, opt_cfg)
        return adamw.apply_updates(p, upd), o, l

    for i in range(args.steps):
        idx = rng.integers(0, xs.shape[0], 64)
        params, opt, l = step(params, opt, xs[idx], ys[idx])
        if i % 25 == 0:
            print(f"step {i:4d} qat-loss {float(l):.4f}")

    def acc(mode):
        logits = cnn.forward(params, cfg, xt, quant_mode=mode, backend="xla")
        return float(jnp.mean(jnp.argmax(logits, -1) == yt))

    print(f"\naccuracy  float: {acc('none'):.3f}   qat(W2A2): "
          f"{acc('qat'):.3f}   packed-integer: {acc('packed'):.3f}")
    print("(packed == deployed Sparq path: quantize+pack at runtime, "
          "packed conv2d, affine dequant)")


if __name__ == "__main__":
    main()

"""Serve a quantized LM with batched requests through the continuous-batching
engine: params are packed offline into ULPPACK lanes (the paper's deployed
path), the decode steps run the packed integer kernels, and the KV cache is
stored sub-byte (kv_bits=4: bit-dense packed words + per-(pos, head) scales),
so a fixed HBM cache budget admits ~4x the concurrent sequences of bf16.

Run:  PYTHONPATH=src python examples/serve_quantized.py

Tensor-parallel variant (mesh-native serving, DESIGN.md §15) on a
CPU-simulated 4-device mesh — packed weights column-parallel, KV cache
sharded over the kv-head axis, token-for-token identical output:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_quantized.py --model-parallel 4

Replica fleet (serve/router.Router, DESIGN.md §17) — two replicas behind
one load-balanced front door, each 2-way tensor-parallel on its own
device group:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python examples/serve_quantized.py \
        --data-parallel 2 --model-parallel 2

(Without enough host devices the fleet falls back to process-local
replicas sharing the host — same Router semantics, shared hardware.)
"""

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.core.quant import QuantConfig
from repro.models import lm
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine
from repro.serve.prepare import prepare_serving_params, serving_param_bytes


def serve_fleet(cfg, params, econf, data, model):
    """Route a request burst through a replica fleet (Router front door)."""
    from repro.launch.mesh import make_serving_mesh
    from repro.serve.router import Router

    n_dev = len(jax.devices())
    if n_dev >= data * model:
        mesh = make_serving_mesh(model=model, data=data)
        router = Router(cfg, params, config=econf, mesh=mesh)
        print(f"fleet: {data} replicas x {model}-way TP on mesh "
              f"{dict(mesh.shape)} ({n_dev} host devices)")
    else:
        router = Router(cfg, params, config=econf, replicas=data)
        print(f"fleet: host has {n_dev} devices (< {data * model}); "
              f"falling back to {data} process-local replicas sharing "
              f"the host")
    rng = np.random.default_rng(0)
    handles = [router.submit(
        rng.integers(0, cfg.vocab_size, 6).astype(np.int32),
        max_new_tokens=8, session=f"user-{i % 2}") for i in range(4)]
    t0 = time.time()
    router.run_to_completion()
    dt = time.time() - t0
    fleet = router.metrics_report()["fleet"]
    tokens = sum(len(h.output) for h in handles)
    print(f"served {len(handles)} requests, {tokens} tokens in {dt:.1f}s "
          f"(fleet decode {fleet['decode_tok_s']} tok/s = sum over "
          f"{fleet['attached']} replicas; spilled {fleet['spilled']})")
    for h in handles:
        print(f"  req {h.uid} -> replica {h.replica}: "
              f"{list(h.request.prompt)} -> {h.output}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-parallel shards (needs that many devices; "
                         "force CPU devices with XLA_FLAGS=--xla_force_"
                         "host_platform_device_count=N)")
    ap.add_argument("--data-parallel", type=int, default=1,
                    help="replica count: >1 serves through the fleet "
                         "Router (least-loaded placement, session "
                         "affinity, spillover)")
    args = ap.parse_args()

    cfg = configs.get_config("stablelm-1.6b", reduced=True).replace(
        d_model=128, num_heads=8, num_kv_heads=8, d_ff=384, num_layers=4,
        vocab_size=2048, param_dtype="float32", compute_dtype="float32",
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2, kv_bits=4))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    econf = EngineConfig(max_batch=2, max_len=64, packed=True)

    raw_bytes = serving_param_bytes(params)
    packed = prepare_serving_params(params, cfg)
    packed_bytes = serving_param_bytes(packed)
    print(f"serving params: {raw_bytes/1e6:.1f} MB float -> "
          f"{packed_bytes/1e6:.1f} MB packed "
          f"({raw_bytes/packed_bytes:.1f}x smaller)")

    if args.data_parallel > 1:
        serve_fleet(cfg, params, econf, args.data_parallel,
                    args.model_parallel)
        return

    mesh = None
    if args.model_parallel > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.model_parallel)
        print(f"serving mesh: {dict(mesh.shape)} over "
              f"{len(jax.devices())} host devices")

    eng = ServingEngine(cfg, params, config=econf, mesh=mesh)
    cap = eng.capacity_report()
    if "shard_plan" in cap:
        print(f"shard plan: {cap['shard_plan']} — packed weights "
              f"column-parallel, kv cache head-sharded")
    bf16_slot = lm.cache_bytes(
        cfg.replace(quant=cfg.quant.replace(kv_bits=0)), 1, 64)
    print(f"kv cache: {cap['cache_bytes_per_slot']/1e3:.1f} KB/slot at "
          f"{cap['kv_bits']}-bit vs {bf16_slot/1e3:.1f} KB bf16 "
          f"({bf16_slot/cap['cache_bytes_per_slot']:.1f}x smaller)")
    rng = np.random.default_rng(0)
    reqs = [Request(uid=i,
                    prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                        np.int32),
                    max_new_tokens=8) for i in range(4)]
    for r in reqs:
        eng.submit(r)
    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    tokens = sum(len(r.output) for r in done)
    print(f"served {len(done)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens/dt:.1f} tok/s on CPU, packed integer path)")
    for r in done:
        print(f"  req {r.uid}: prompt={list(r.prompt)} -> {r.output}")


if __name__ == "__main__":
    main()

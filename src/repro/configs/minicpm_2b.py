"""minicpm-2b dense (llama-like), WSD schedule [arXiv:2404.06395]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="minicpm-2b", family="dense",
        num_layers=40, d_model=2304, num_heads=36, num_kv_heads=36,
        d_ff=5760, vocab_size=122753, tie_embeddings=True,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=2),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, d_model=72, num_heads=4,
                                 num_kv_heads=4, d_ff=128, vocab_size=512)

"""mixtral-8x22b MoE 8e top-2, SWA [arXiv:2401.04088]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b", family="moe",
        num_layers=56, d_model=6144, num_heads=48, num_kv_heads=8,
        d_ff=16384, vocab_size=32768, sliding_window=4096,
        num_experts=8, num_experts_per_tok=2, moe_stride=1,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="full", microbatches=8,
                                fsdp_over_pod=True, eightbit_moments=True),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, sliding_window=8, num_experts=4, moe_group_size=16,
        parallel=ParallelConfig(remat="none", microbatches=1))

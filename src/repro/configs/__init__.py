"""Architecture registry.

``get_config(name)`` returns the full assigned configuration;
``get_config(name, reduced=True)`` returns the smoke-test reduction of the
same family (same code paths, tiny dims — suitable for CPU).
"""

from __future__ import annotations

from repro.configs import (granite_3_8b, jamba_1_5_large_398b, minicpm_2b,
                           mixtral_8x22b, mixtral_8x7b, qwen1_5_32b,
                           qwen2_vl_2b, seamless_m4t_medium, sparq_cnn,
                           stablelm_1_6b, xlstm_1_3b)
from repro.configs.base import ModelConfig, ParallelConfig  # noqa: F401

_MODULES = {
    "xlstm-1.3b": xlstm_1_3b,
    "jamba-1.5-large-398b": jamba_1_5_large_398b,
    "stablelm-1.6b": stablelm_1_6b,
    "qwen1.5-32b": qwen1_5_32b,
    "granite-3-8b": granite_3_8b,
    "minicpm-2b": minicpm_2b,
    "seamless-m4t-medium": seamless_m4t_medium,
    "qwen2-vl-2b": qwen2_vl_2b,
    "mixtral-8x22b": mixtral_8x22b,
    "mixtral-8x7b": mixtral_8x7b,
    "sparq-cnn": sparq_cnn,
}

ARCH_NAMES = [n for n in _MODULES if n != "sparq-cnn"]
ALL_NAMES = list(_MODULES)


def get_config(name: str, *, reduced: bool = False) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown architecture {name!r}; "
                       f"available: {sorted(_MODULES)}")
    mod = _MODULES[name]
    return mod.reduced_config() if reduced else mod.full_config()

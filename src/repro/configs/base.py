"""Config dataclasses: model architecture, quantization, parallelism, shapes.

Every assigned architecture is a ``ModelConfig`` in its own module under
repro/configs/; ``repro.configs.get_config(name)`` returns the full config and
``get_config(name, reduced=True)`` the smoke-test reduction of the same
family (same code paths, tiny dims).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

from repro.core.quant import QuantConfig


@dataclasses.dataclass(frozen=True)
class ParallelConfig:
    """How this config shards on the production mesh (DESIGN.md §6)."""

    fsdp_axis: str = "data"          # parameter/optimizer sharding axis
    tensor_axis: str = "model"       # Megatron TP axis
    fsdp_over_pod: bool = False      # also shard params over the pod axis
    expert_parallel: bool = False    # true EP (experts divide tensor axis)
    sequence_parallel: bool = False  # shard long-context KV/activations
    remat: str = "block"             # 'none' | 'block' | 'full'
    microbatches: int = 1            # gradient-accumulation steps
    eightbit_moments: bool = False   # int8 Adam moments (jamba-scale)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|audio|vlm|cnn
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None
    # --- attention flavour ---
    qkv_bias: bool = False
    sliding_window: int = 0           # 0 = full attention
    rope_theta: float = 10000.0
    mrope: bool = False               # qwen2-vl multimodal RoPE
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    # --- MoE ---
    num_experts: int = 0
    num_experts_per_tok: int = 2
    moe_stride: int = 1               # MoE every k-th layer
    capacity_factor: float = 1.25
    moe_group_size: int = 256         # dispatch blocking (DESIGN.md §6)
    # --- hybrid (jamba): attention every attn_stride-th layer, else mamba ---
    attn_stride: int = 0
    ssm_state_dim: int = 16
    ssm_conv_width: int = 4
    ssm_expand: int = 2
    ssm_dt_rank: int = 0              # 0 -> ceil(d_model/16)
    # --- xlstm ---
    slstm_every: int = 0              # sLSTM every k-th block (0 = none)
    mlstm_proj_factor: float = 2.0
    # --- encoder-decoder ---
    encoder_layers: int = 0           # >0 => enc-dec (seamless)
    # --- frontends (stub modality encoders) ---
    frontend: str = "none"            # none|audio|vision
    frontend_dim: int = 0             # precomputed embedding dim from stub
    # --- misc ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    # --- integration of the paper's technique ---
    # quant.kv_bits additionally selects the serving KV-cache storage
    # precision (0/16 bf16, 8 int8, 4/2 bit-dense packed; DESIGN.md §13) —
    # a deployment knob, orthogonal to the w_bits/a_bits compute lattice.
    quant: QuantConfig = dataclasses.field(default_factory=QuantConfig)
    parallel: ParallelConfig = dataclasses.field(
        default_factory=ParallelConfig)
    # --- CNN (sparq-cnn only) ---
    cnn_channels: Tuple[int, ...] = ()
    cnn_kernel: int = 7
    cnn_input_hw: int = 256
    cnn_num_classes: int = 10

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 — hardware-aligned and
        divisible by the tensor axis (embedding/logits shard over 'model')."""
        if self.vocab_size == 0:
            return 0
        return -(-self.vocab_size // 256) * 256

    @property
    def dt_rank(self) -> int:
        return self.ssm_dt_rank or -(-self.d_model // 16)

    @property
    def is_encoder_decoder(self) -> bool:
        return self.encoder_layers > 0

    def layer_kind(self, i: int) -> str:
        """Block type for decoder layer i: attn | mamba | slstm | mlstm."""
        if self.family == "ssm" and self.slstm_every:
            return "slstm" if (i % self.slstm_every == self.slstm_every - 1) \
                else "mlstm"
        if self.family == "ssm":
            return "mlstm"
        if self.attn_stride:
            # jamba 1:7 — one attention layer per attn_stride layers.
            return "attn" if (i % self.attn_stride == self.attn_stride // 2) \
                else "mamba"
        return "attn"

    def layer_is_moe(self, i: int) -> bool:
        if self.num_experts == 0:
            return False
        return i % self.moe_stride == self.moe_stride - 1

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ------------------------------------------------------------------
    # Parameter-count accounting (roofline MODEL_FLOPS; DESIGN.md §9)
    # ------------------------------------------------------------------

    def param_counts(self) -> dict:
        """Analytic total / active parameter counts (embedding included)."""
        d, v = self.d_model, self.vocab_size
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = v * d * (1 if self.tie_embeddings else 2)
        active = total
        di = self.ssm_expand * d

        def attn_params():
            return d * hd * (nq + 2 * nkv) + nq * hd * d + \
                (hd * (nq + 2 * nkv) if self.qkv_bias else 0)

        def mlp_params():
            return 3 * d * self.d_ff

        def mamba_params():
            dtr = self.dt_rank
            return (d * 2 * di + self.ssm_conv_width * di
                    + di * (dtr + 2 * self.ssm_state_dim)
                    + dtr * di + di * self.ssm_state_dim + di + di * d)

        def mlstm_params():
            inner = int(self.mlstm_proj_factor * d)
            return d * 2 * inner + 3 * inner * inner + 3 * inner + \
                inner * d

        def slstm_params():
            return 4 * d * d + 4 * d * d + 4 * d + int(d * 4 / 3 * d) * 2

        n_dec = self.num_layers
        for i in range(n_dec):
            kind = self.layer_kind(i)
            if kind == "attn":
                total += attn_params(); active += attn_params()
            elif kind == "mamba":
                total += mamba_params(); active += mamba_params()
            elif kind == "mlstm":
                total += mlstm_params(); active += mlstm_params()
            elif kind == "slstm":
                total += slstm_params(); active += slstm_params()
            if kind in ("attn", "mamba"):
                if self.layer_is_moe(i):
                    total += self.num_experts * mlp_params() + \
                        d * self.num_experts
                    active += self.num_experts_per_tok * mlp_params() + \
                        d * self.num_experts
                elif self.d_ff:
                    total += mlp_params(); active += mlp_params()
        if self.is_encoder_decoder:
            enc = self.encoder_layers * (attn_params() + mlp_params())
            cross = self.num_layers * attn_params()
            total += enc + cross
            active += enc + cross
        return {"total": total, "active": active}

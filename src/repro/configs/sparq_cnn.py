"""sparq-cnn — the paper's own conv2d benchmark network (Fig. 4/5)."""

from repro.configs.base import ModelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="sparq-cnn", family="cnn",
        num_layers=3, d_model=0, num_heads=1, num_kv_heads=1, d_ff=0,
        vocab_size=0,
        cnn_channels=(32, 32, 64), cnn_kernel=7, cnn_input_hw=256,
        cnn_num_classes=10,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(cnn_channels=(8, 8), cnn_kernel=3,
                                 cnn_input_hw=16)

"""jamba-1.5-large-398b — Mamba+attention 1:7 hybrid, MoE 16e top-2 [arXiv:2403.19887]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-1.5-large-398b", family="hybrid",
        num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8,
        d_ff=24576, vocab_size=65536,
        attn_stride=8,                    # 1 attention : 7 mamba
        num_experts=16, num_experts_per_tok=2, moe_stride=2,
        ssm_state_dim=16, ssm_conv_width=4, ssm_expand=2,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="full", microbatches=16,
                                fsdp_over_pod=True, expert_parallel=True,
                                eightbit_moments=True),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
        d_ff=128, vocab_size=512, attn_stride=2, num_experts=4,
        moe_stride=2, moe_group_size=16,
        parallel=ParallelConfig(remat="none", microbatches=1))

"""qwen1.5-32b dense, QKV bias [hf:Qwen/Qwen1.5-32B]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen1.5-32b", family="dense",
        num_layers=64, d_model=5120, num_heads=40, num_kv_heads=40,
        d_ff=27392, vocab_size=152064, qkv_bias=True,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=4,
                                eightbit_moments=True),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=4, d_ff=128, vocab_size=512)

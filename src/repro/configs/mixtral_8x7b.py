"""mixtral-8x7b MoE 8e top-2, SWA [arXiv:2401.04088]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x7b", family="moe",
        num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=14336, vocab_size=32000, sliding_window=4096,
        num_experts=8, num_experts_per_tok=2, moe_stride=1,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=4),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(
        num_layers=2, d_model=64, num_heads=4, num_kv_heads=2, d_ff=128,
        vocab_size=512, sliding_window=8, num_experts=4, moe_group_size=16,
        parallel=ParallelConfig(remat="none", microbatches=1))

"""seamless-m4t-medium enc-dec audio (stub frontend) [arXiv:2308.11596]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="seamless-m4t-medium", family="audio",
        num_layers=12, encoder_layers=12, d_model=1024, num_heads=16,
        num_kv_heads=16, d_ff=4096, vocab_size=256206,
        frontend="audio", frontend_dim=512,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block"),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, encoder_layers=2, d_model=64,
                                 num_heads=4, num_kv_heads=4, d_ff=128,
                                 vocab_size=512, frontend_dim=32)

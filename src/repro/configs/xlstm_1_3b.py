"""xlstm-1.3b — sLSTM + mLSTM blocks [arXiv:2405.04517]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="xlstm-1.3b", family="ssm",
        num_layers=48, d_model=2048, num_heads=4, num_kv_heads=4,
        d_ff=0, vocab_size=50304,
        slstm_every=8, mlstm_proj_factor=2.0,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=4),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(
        num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
        vocab_size=512, slstm_every=2)

"""granite-3-8b dense GQA [hf:ibm-granite/granite-3.0-8b-base]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-8b", family="dense",
        num_layers=40, d_model=4096, num_heads=32, num_kv_heads=8,
        d_ff=12800, vocab_size=49155, tie_embeddings=True,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=2),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=2, d_ff=128, vocab_size=512)

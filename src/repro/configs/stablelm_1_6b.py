"""stablelm-1.6b dense [hf:stabilityai/stablelm-2-1_6b]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="stablelm-1.6b", family="dense",
        num_layers=24, d_model=2048, num_heads=32, num_kv_heads=32,
        d_ff=5632, vocab_size=100352,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block", microbatches=2),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=4, d_ff=128, vocab_size=512)

"""qwen2-vl-2b VLM backbone, M-RoPE, stub vision frontend [arXiv:2409.12191]."""

from repro.configs.base import ModelConfig, ParallelConfig
from repro.core.quant import QuantConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm",
        num_layers=28, d_model=1536, num_heads=12, num_kv_heads=2,
        d_ff=8960, vocab_size=151936, head_dim=128,
        mrope=True, mrope_sections=(16, 24, 24),
        frontend="vision", frontend_dim=1280, tie_embeddings=True,
        quant=QuantConfig(enabled=True, w_bits=2, a_bits=2),
        parallel=ParallelConfig(remat="block"),
    )


def reduced_config() -> ModelConfig:
    return full_config().replace(num_layers=2, d_model=64, num_heads=4,
                                 num_kv_heads=2, d_ff=128, vocab_size=512,
                                 head_dim=16, mrope_sections=(2, 3, 3),
                                 frontend_dim=32)

"""Deterministic, sharded, checkpointable synthetic LM data pipeline.

Production shape: an infinite token stream partitioned by (host, shard) with
a counter-based PRNG so that (a) every batch is reproducible from (seed,
step) alone, (b) restoring `step` from a checkpoint resumes the exact stream
(no replay drift), and (c) elastic restarts with a different data-parallel
degree re-partition the stream without changing the global sequence.

The synthetic distribution is a Zipf-ish unigram mix with short repeated
motifs — enough structure that a ~100M model's loss visibly drops in a few
hundred steps (examples/train_lm.py) while requiring no external corpus in
this offline container.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    motif_len: int = 8
    motif_count: int = 64


class SyntheticLMStream:
    """step -> batch dict, stateless per step (counter-based)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # fixed unigram distribution (Zipf) + motif table
        ranks = np.arange(1, v + 1)
        probs = 1.0 / ranks ** 1.1
        self.probs = probs / probs.sum()
        self.motifs = root.integers(
            0, v, size=(cfg.motif_count, cfg.motif_len))

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        b, s = cfg.global_batch, cfg.seq_len
        tokens = rng.choice(cfg.vocab_size, size=(b, s + 1), p=self.probs)
        # plant motifs: ~25% of positions continue a motif deterministically
        n_plants = (b * s) // (4 * cfg.motif_len)
        rows = rng.integers(0, b, n_plants)
        cols = rng.integers(0, s + 1 - cfg.motif_len, n_plants)
        which = rng.integers(0, cfg.motif_count, n_plants)
        for r, c, w in zip(rows, cols, which):
            tokens[r, c:c + cfg.motif_len] = self.motifs[w]
        return {"tokens": tokens[:, :-1].astype(np.int32),
                "labels": tokens[:, 1:].astype(np.int32)}

    def state(self, step: int) -> dict:
        return {"seed": self.cfg.seed, "step": int(step)}

    @classmethod
    def from_state(cls, cfg: DataConfig, state: dict) -> "SyntheticLMStream":
        assert state["seed"] == cfg.seed, "data seed mismatch on restore"
        return cls(cfg)

"""Three-term roofline from a compiled dry-run artifact (DESIGN.md §9).

  compute    = HLO_FLOPs(per-device) / peak_FLOP/s
  memory     = HLO_bytes(per-device) / HBM_bw
  collective = sum(collective operand bytes, per-device) / ICI link bw

cost_analysis() gives FLOPs/bytes; collective bytes are parsed from the
compiled (post-SPMD) HLO text, summing operand sizes of all-gather /
all-reduce / reduce-scatter / all-to-all / collective-permute ops.
"""

from __future__ import annotations

import re

from repro.roofline import hw

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "bf16": 2, "f16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# operand type tokens like  bf16[16,4096]{1,0}  inside a collective call
_TYPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


_OP_RE = re.compile(
    r"=\s+((?:\(?[\w\[\]{},\s]+?\)?))\s+("
    + "|".join(_COLLECTIVES) + r")(-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def collective_bytes(hlo_text: str) -> dict:
    """Sum *operand* bytes per collective kind from compiled (post-SPMD) HLO.

    Compiled HLO prints operands by name only, so we read the RESULT type and
    convert to operand bytes per kind: all-reduce / all-to-all / permute have
    operand == result; all-gather operand = result / group; reduce-scatter
    operand = result * group (group size parsed from replica_groups=[n,g]).
    """
    out = {k: 0 for k in _COLLECTIVES}
    count = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        stripped = line.strip()
        if "-done(" in stripped:
            continue  # -start carries the shapes; -done would double count
        m = _OP_RE.search(stripped)
        if not m:
            continue
        result_types, kind = m.group(1), m.group(2)
        nbytes = sum(_shape_bytes(d, s)
                     for d, s in _TYPE_RE.findall(result_types))
        if nbytes == 0:
            continue
        gm = _GROUPS_RE.search(stripped)
        group = int(gm.group(2)) if gm else 1
        if kind == "all-gather":
            nbytes = nbytes // max(group, 1)
        elif kind == "reduce-scatter":
            nbytes = nbytes * max(group, 1)
        if kind == "all-gather" and "-start(" in stripped:
            # result of -start is a (operand, result) tuple: halve the
            # overcount from summing both tuple components
            nbytes = nbytes // 2
        out[kind] += nbytes
        count[kind] += 1
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    out["counts"] = count
    return out


def roofline_terms(cost: dict, coll_bytes: int, chips: int) -> dict:
    flops = float(cost.get("flops", 0.0) or 0.0)
    nbytes = float(cost.get("bytes accessed", 0.0) or 0.0)
    return {
        "compute_s": flops / hw.PEAK_FLOPS_BF16,
        "memory_s": nbytes / hw.HBM_BW,
        "collective_s": coll_bytes / hw.ICI_BW_PER_LINK,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": nbytes,
        "collective_bytes_per_device": coll_bytes,
        "chips": chips,
    }


def dominant_term(terms: dict) -> str:
    vals = {"compute": terms["compute_s"], "memory": terms["memory_s"],
            "collective": terms["collective_s"]}
    return max(vals, key=vals.get)


# ---------------------------------------------------------------------------
# Analytic MODEL_FLOPS (useful-work accounting; DESIGN.md §9)
# ---------------------------------------------------------------------------

def model_flops(cfg, shape) -> float:
    """6*N_active*D for training, 2*N_active*tokens for serving, plus the
    attention term (full S^2 for dense, S*window for SWA, linear for
    SSM/xLSTM whose compute is inside N)."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    s, gb = shape.seq_len, shape.global_batch
    hd = cfg.resolved_head_dim
    nq = cfg.num_heads
    attn_layers = sum(1 for i in range(cfg.num_layers)
                      if cfg.layer_kind(i) == "attn")
    attn_layers += cfg.encoder_layers

    if shape.kind == "train":
        tokens = gb * s
        kv = min(s, cfg.sliding_window) if cfg.sliding_window else s
        attn = 3 * (4.0 * gb * nq * s * kv * hd) * attn_layers
        return 6.0 * n_active * tokens + attn
    if shape.kind == "prefill":
        tokens = gb * s
        kv = min(s, cfg.sliding_window) if cfg.sliding_window else s
        attn = (4.0 * gb * nq * s * kv * hd) * attn_layers
        return 2.0 * n_active * tokens + attn
    # decode: one token against a seq_len cache
    kv = min(s, cfg.sliding_window) if cfg.sliding_window else s
    attn = (4.0 * gb * nq * 1 * kv * hd) * attn_layers
    return 2.0 * n_active * gb + attn


def summarize_cell(arch, shape_name, mesh_name, chips, cost, coll,
                   mflops) -> dict:
    terms = roofline_terms(cost, coll["total"], chips)
    dom = dominant_term(terms)
    hlo_global = terms["hlo_flops_per_device"] * chips
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips,
        **{k: terms[k] for k in ("compute_s", "memory_s", "collective_s")},
        "dominant": dom,
        "hlo_flops_per_device": terms["hlo_flops_per_device"],
        "hlo_bytes_per_device": terms["hlo_bytes_per_device"],
        "collective_bytes_per_device": terms["collective_bytes_per_device"],
        "collective_counts": coll.get("counts", {}),
        "model_flops": mflops,
        "useful_ratio": (mflops / hlo_global) if hlo_global else float("nan"),
    }

"""TPU v5e hardware constants for the roofline model (assignment-specified)."""

PEAK_FLOPS_BF16 = 197e12     # FLOP/s per chip
PEAK_OPS_INT8 = 394e12       # int8 ops/s per chip (2x bf16)
HBM_BW = 819e9               # bytes/s per chip
ICI_BW_PER_LINK = 50e9       # bytes/s per ICI link
HBM_PER_CHIP = 16 * 2**30    # bytes
VMEM_PER_CORE = 16 * 2**20   # bytes (block-spec sizing budget)

"""sparq-cnn: the paper's conv2d benchmark network as a QAT-able model.

A small channel-first... (TPU-native: NHWC) CNN whose conv layers run:
  * 'qat'    — PACT-clipped activations + LSQ weights, float conv (training);
  * 'packed' — the deployed Sparq path: runtime quantize+P1-pack over
               channels, packed conv2d kernel, affine dequant.

Deployment is two-phase, mirroring the paper's offline planning (§IV):
``conv_prepare`` / ``prepare_packed_params`` quantize + pack each conv
layer's weights ONCE (P1 lanes or bit-dense words) and ``layer_plans`` builds
the per-layer ``KernelPlan``s; the forward pass then only quantizes
activations and dispatches through the prepared plan — no per-call weight
re-packing.  Un-prepared params still work (weights are packed inline), which
keeps QAT-time packed evaluation simple.

This model backs benchmarks/fig4_conv2d.py and fig5_precision_sweep.py and
examples/train_cnn_qat.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, quant
from repro.core.packing import PackSpec
from repro.kernels import ops
from repro.kernels import plan as plan_lib
from repro.models import common


def conv_init(key, fh, fw, cin, cout, qcfg, dtype=jnp.float32):
    w = jax.random.normal(key, (fh, fw, cin, cout), jnp.float32) \
        / np.sqrt(fh * fw * cin)
    p = {"kernel": w.astype(dtype)}
    if qcfg.enabled:
        p["w_step"] = quant.init_step_from_data(w, qcfg.w_bits, True)
        p["alpha"] = jnp.float32(4.0)   # PACT clip
    return p


def conv_layer_spec(x_shape, w_shape, qcfg, *, padding: str = "SAME",
                    weight_store: str = "lanes", w_packed=None) -> PackSpec:
    """Per-layer chosen lane layout for a conv2d (DESIGN.md §16).

    ``x_shape``/``w_shape`` are the UNPACKED [N, H, W, Cin] / [Fh, Fw, Cin,
    Co].  Resolves through the active autotune layout cache
    (autotune.conv2d_layout_for), defaulting to the config spec; a lanes
    leaf (``w_packed``) whose dtype/channel count contradicts the resolved
    layout (cache changed after packing) falls back to the config spec.
    """
    from repro.kernels import autotune

    base = PackSpec.from_config(qcfg)
    spec = autotune.conv2d_layout_for(tuple(x_shape), tuple(w_shape), base,
                                      padding=padding, backend="auto",
                                      weight_store=weight_store)
    if weight_store == "lanes" and w_packed is not None and spec != base:
        cin = w_shape[2]
        if (w_packed.dtype != spec.lane_dtype
                or w_packed.shape[2] != -(-cin // spec.n_pack)):
            return base
    return spec


def conv_prepare(p, qcfg, *, weight_store: str = "lanes",
                 spec: PackSpec | None = None):
    """Offline per-layer weight preparation (done once, not per forward).

    Quantizes the float kernel to the w_bits lattice and stores it either as
    P1 lanes ('lanes' -> ``w_packed``) or bit-dense int32 words ('dense' ->
    ``w_words``, expanded in the conv kernel prologue).  The float kernel is
    dropped from the prepared layer.  ``spec`` pins the lane layout (the
    per-layer chosen spec from ``conv_layer_spec`` — preparation happens
    once offline, so the layout decision is made by the caller who knows
    the input shape); defaults to the config-global spec.
    """
    spec = spec if spec is not None else PackSpec.from_config(qcfg)
    w = p["kernel"].astype(jnp.float32)
    w_scale = p.get("w_step", quant.calibrate_absmax(w, qcfg.w_bits)[0])
    w_zp = qcfg.w_zero_point
    q_w = quant.quantize_affine(w, w_scale, w_zp, qcfg.w_bits)
    out = {"alpha": p.get("alpha", jnp.float32(4.0)),
           "w_scale": jnp.asarray(w_scale, jnp.float32),
           "w_zp": jnp.int32(w_zp)}
    if weight_store == "dense":
        out["w_words"] = ops.dense_store_conv_weights(q_w, qcfg.w_bits)
    elif weight_store == "lanes":
        out["w_packed"] = packing.pack_weights(q_w, spec, axis=2)
    else:
        raise ValueError(weight_store)
    return out


def prepare_packed_params(params, cfg, *, weight_store: str = "lanes",
                          x_shape=None, padding: str = "SAME",
                          autotune: bool = False):
    """Convert a trained/QAT param tree for packed serving (weights packed
    once); the float stem and head are untouched (they run un-quantized).

    With ``x_shape`` ([N, H, W, 3] network input) each layer packs under its
    per-layer *chosen* lane layout (``conv_layer_spec``; SAME padding keeps
    H, W constant through the stack); ``autotune=True`` additionally sweeps
    the layout family per layer first (autotune.tune_conv2d_layout) — the
    tuner weighs layouts *before* the bytes are packed.  Without ``x_shape``
    every layer uses the config-global spec (pre-layout-sweep behavior).
    """
    chans = cfg.cnn_channels
    layers = []
    for i, p in enumerate(params["layers"]):
        spec = None
        if x_shape is not None:
            n, h, w, _ = x_shape
            cin = chans[i - 1] if i > 0 else chans[0]
            cout = chans[i]
            fh = fw = cfg.cnn_kernel
            xs, ws = (n, h, w, cin), (fh, fw, cin, cout)
            if autotune:
                from repro.kernels import autotune as autotune_lib
                autotune_lib.tune_conv2d_layout(
                    xs, ws, PackSpec.from_config(cfg.quant),
                    padding=padding, weight_store=weight_store)
            spec = conv_layer_spec(xs, ws, cfg.quant, padding=padding,
                                   weight_store=weight_store)
        layers.append(conv_prepare(p, cfg.quant, weight_store=weight_store,
                                   spec=spec))
    return {"stem": params["stem"], "layers": layers,
            "head": params["head"]}


def layer_plans(params, cfg, x_shape, *, padding: str = "SAME",
                backend: str = "auto", autotune: bool = False):
    """Per-conv-layer KernelPlans for an input [N, H, W, 3] shape.

    SAME padding keeps H, W constant through the stack, so each layer's plan
    differs only in channel counts.  Returns a list aligned with
    params['layers'].

    ``autotune=True`` is the opt-in warm-tune pass (DESIGN.md §14): each
    layer signature missing from the active tuning cache is benchmarked
    once (kernels/autotune.tune_packed_conv2d) before planning, so the
    returned plans are cache-backed; the caller persists the cache
    (``autotune.active_cache().save()``) to tune a deployment once offline.
    """
    n, h, w, _ = x_shape
    chans = cfg.cnn_channels
    plans = []
    for i, p in enumerate(params["layers"]):
        cin = chans[i - 1] if i > 0 else chans[0]
        cout = chans[i]
        fh = fw = cfg.cnn_kernel
        # Per-layer chosen lane layout, resolved exactly as pack time did
        # (conv_layer_spec: active layout cache, config default, leaf
        # evidence guard) — the plan records which layout the stored bytes
        # use (DESIGN.md §16).
        if "w_packed" in p:
            wp = p["w_packed"]
            fh, fw, cout = int(wp.shape[0]), int(wp.shape[1]), int(wp.shape[3])
            store, k_full = "lanes", None
            spec = conv_layer_spec((n, h, w, cin), (fh, fw, cin, cout),
                                   cfg.quant, padding=padding,
                                   weight_store=store, w_packed=wp)
            cp = int(wp.shape[2])
            if wp.dtype != spec.lane_dtype or cp != -(-cin // spec.n_pack):
                raise ValueError(
                    f"layers[{i}]: packed bytes ({wp.dtype}, cp={cp}) do "
                    f"not match the resolved lane layout {spec} for "
                    f"cin={cin}; re-run prepare_packed_params under the "
                    f"active autotune layout cache")
            w_shape = tuple(wp.shape)
        elif "w_words" in p:
            ww = p["w_words"]
            fh, fw, cout = int(ww.shape[0]), int(ww.shape[1]), int(ww.shape[3])
            store, k_full = "dense", cin
            spec = conv_layer_spec((n, h, w, cin), (fh, fw, cin, cout),
                                   cfg.quant, padding=padding,
                                   weight_store=store)
            cp = -(-cin // spec.n_pack)
            w_shape = tuple(ww.shape)
        else:
            fh, fw, cin, cout = (int(d) for d in p["kernel"].shape)
            store, k_full = "lanes", None
            spec = conv_layer_spec((n, h, w, cin), (fh, fw, cin, cout),
                                   cfg.quant, padding=padding,
                                   weight_store=store)
            cp = -(-cin // spec.n_pack)
            w_shape = (fh, fw, cp, cout)
        if autotune:
            from repro.kernels import autotune as autotune_lib
            autotune_lib.tune_packed_conv2d(
                (n, h, w, cp), w_shape, spec, padding=padding,
                backend=backend, weight_store=store, k_full=k_full)
        plans.append(plan_lib.plan_packed_conv2d(
            (n, h, w, cp), w_shape, spec, padding=padding, backend=backend,
            weight_store=store, k_full=k_full))
    return plans


def conv_apply(p, x, qcfg, *, quant_mode="none", padding="SAME",
               backend="auto", plan=None):
    if quant_mode == "packed" and qcfg.enabled:
        prepared = "w_packed" in p or "w_words" in p
        if plan is not None:
            # the plan records which lane layout the stored bytes use
            spec = plan.spec
        else:
            xs = tuple(int(d) for d in x.shape)
            if prepared:
                wp0 = p.get("w_packed", p.get("w_words"))
                ws = (int(wp0.shape[0]), int(wp0.shape[1]), xs[-1],
                      int(wp0.shape[3]))
                spec = conv_layer_spec(
                    xs, ws, qcfg, padding=padding,
                    weight_store="dense" if "w_words" in p else "lanes",
                    w_packed=p.get("w_packed"))
            else:
                spec = conv_layer_spec(xs, tuple(p["kernel"].shape), qcfg,
                                       padding=padding)
        if prepared:
            w_scale, w_zp = p["w_scale"], p["w_zp"]
            wp = p.get("w_packed", p.get("w_words"))
            weight_store = "dense" if "w_words" in p else "lanes"
            fh, fw = wp.shape[:2]
        else:
            # un-prepared fallback (QAT-time eval): pack inline
            w = p["kernel"].astype(jnp.float32)
            w_scale = p.get("w_step", quant.calibrate_absmax(w,
                                                             qcfg.w_bits)[0])
            w_zp = qcfg.w_zero_point
            q_w = quant.quantize_affine(w, w_scale, w_zp, qcfg.w_bits)
            wp = packing.pack_weights(q_w, spec, axis=2)
            weight_store = "lanes"
            fh, fw = p["kernel"].shape[:2]
        # activations: PACT range [0, alpha] -> z=0 lattice
        alpha = p.get("alpha", jnp.float32(4.0))
        a_scale = alpha / qcfg.qmax_a
        xq = quant.quantize_affine(jnp.clip(x, 0.0, alpha), a_scale, 0,
                                   qcfg.a_bits)
        xp = packing.pack_activations(xq, spec, axis=-1)
        k_full = x.shape[-1] if weight_store == "dense" else None
        acc = ops.packed_conv2d(xp, wp, spec, padding=padding,
                                backend=backend, weight_store=weight_store,
                                k_full=k_full, plan=plan).astype(jnp.float32)
        # zero-point correction (z_a = 0): acc - z_w * patch_sums(a)
        ones = jnp.ones((fh, fw, x.shape[-1], 1), jnp.int32)
        psum = jax.lax.conv_general_dilated(
            xq, ones, (1, 1), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        return a_scale * w_scale * (acc - w_zp * psum)
    w = p["kernel"].astype(jnp.float32)
    xx = x.astype(jnp.float32)
    if quant_mode == "qat" and qcfg.enabled:
        w = quant.lsq_fake_quant(w, p["w_step"], qcfg.w_bits, True)
        alpha = p["alpha"]
        xc = quant.pact_clip(xx, alpha, qcfg.a_bits)
        xx = quant.fake_quant(xc, alpha / qcfg.qmax_a, jnp.float32(0.0),
                              qcfg.a_bits)
    return jax.lax.conv_general_dilated(
        xx, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))


def init_params(key, cfg):
    chans = cfg.cnn_channels
    ks = jax.random.split(key, len(chans) + 1)
    layers = []
    cin = chans[0]  # input is pre-embedded to chans[0] by a stem below
    stem = conv_init(ks[0], 3, 3, 3, chans[0], cfg.quant)
    for i, cout in enumerate(chans):
        layers.append(conv_init(ks[i], cfg.cnn_kernel, cfg.cnn_kernel,
                                cin, cout, cfg.quant))
        cin = cout
    head = common.dense_init(ks[-1], cin, cfg.cnn_num_classes)
    return {"stem": stem, "layers": layers, "head": head}


def forward(params, cfg, x, *, quant_mode="none", backend="auto",
            plans=None):
    """x: [N, H, W, 3] image -> logits [N, classes].

    ``plans`` (from ``layer_plans``) routes each conv through its prebuilt
    KernelPlan; without it, plans are looked up from the memoized planners.
    """
    h = jax.nn.relu(conv_apply(params["stem"], x, cfg.quant,
                               quant_mode="none"))
    for i, p in enumerate(params["layers"]):
        plan = plans[i] if plans is not None else None
        h = jax.nn.relu(conv_apply(p, h, cfg.quant, quant_mode=quant_mode,
                                   backend=backend, plan=plan))
    pooled = jnp.mean(h, axis=(1, 2))
    return common.dense_apply(params["head"], pooled,
                              compute_dtype=jnp.float32)

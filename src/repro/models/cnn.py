"""sparq-cnn: the paper's conv2d benchmark network as a QAT-able model.

A small channel-first... (TPU-native: NHWC) CNN whose conv layers run:
  * 'qat'    — PACT-clipped activations + LSQ weights, float conv (training);
  * 'packed' — the deployed Sparq path: runtime quantize+P1-pack over
               channels, packed conv2d kernel, affine dequant.

This model backs benchmarks/fig4_conv2d.py and fig5_precision_sweep.py and
examples/train_cnn_qat.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, quant
from repro.core.packing import PackSpec
from repro.kernels import ops
from repro.models import common


def conv_init(key, fh, fw, cin, cout, qcfg, dtype=jnp.float32):
    w = jax.random.normal(key, (fh, fw, cin, cout), jnp.float32) \
        / np.sqrt(fh * fw * cin)
    p = {"kernel": w.astype(dtype)}
    if qcfg.enabled:
        p["w_step"] = quant.init_step_from_data(w, qcfg.w_bits, True)
        p["alpha"] = jnp.float32(4.0)   # PACT clip
    return p


def conv_apply(p, x, qcfg, *, quant_mode="none", padding="SAME",
               backend="auto"):
    if quant_mode == "packed" and qcfg.enabled:
        spec = PackSpec(qcfg.w_bits, qcfg.a_bits, jnp.dtype(qcfg.lane_dtype),
                        qcfg.n_pack)
        w = p["kernel"].astype(jnp.float32)
        w_scale = p.get("w_step", quant.calibrate_absmax(w, qcfg.w_bits)[0])
        w_zp = qcfg.w_zero_point
        q_w = quant.quantize_affine(w, w_scale, w_zp, qcfg.w_bits)
        wp = packing.pack_weights(q_w, spec, axis=2)
        # activations: PACT range [0, alpha] -> z=0 lattice
        alpha = p.get("alpha", jnp.float32(4.0))
        a_scale = alpha / qcfg.qmax_a
        xq = quant.quantize_affine(jnp.clip(x, 0.0, alpha), a_scale, 0,
                                   qcfg.a_bits)
        xp = packing.pack_activations(xq, spec, axis=-1)
        acc = ops.packed_conv2d(xp, wp, spec, padding=padding,
                                backend=backend).astype(jnp.float32)
        # zero-point correction (z_a = 0): acc - z_w * patch_sums(a)
        ones = jnp.ones(p["kernel"].shape[:3] + (1,), jnp.int32)
        psum = jax.lax.conv_general_dilated(
            xq, ones, (1, 1), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        return a_scale * w_scale * (acc - w_zp * psum)
    w = p["kernel"].astype(jnp.float32)
    xx = x.astype(jnp.float32)
    if quant_mode == "qat" and qcfg.enabled:
        w = quant.lsq_fake_quant(w, p["w_step"], qcfg.w_bits, True)
        alpha = p["alpha"]
        xc = quant.pact_clip(xx, alpha, qcfg.a_bits)
        xx = quant.fake_quant(xc, alpha / qcfg.qmax_a, jnp.float32(0.0),
                              qcfg.a_bits)
    return jax.lax.conv_general_dilated(
        xx, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))


def init_params(key, cfg):
    chans = cfg.cnn_channels
    ks = jax.random.split(key, len(chans) + 1)
    layers = []
    cin = chans[0]  # input is pre-embedded to chans[0] by a stem below
    stem = conv_init(ks[0], 3, 3, 3, chans[0], cfg.quant)
    for i, cout in enumerate(chans):
        layers.append(conv_init(ks[i], cfg.cnn_kernel, cfg.cnn_kernel,
                                cin, cout, cfg.quant))
        cin = cout
    head = common.dense_init(ks[-1], cin, cfg.cnn_num_classes)
    return {"stem": stem, "layers": layers, "head": head}


def forward(params, cfg, x, *, quant_mode="none", backend="auto"):
    """x: [N, H, W, 3] image -> logits [N, classes]."""
    h = jax.nn.relu(conv_apply(params["stem"], x, cfg.quant,
                               quant_mode="none"))
    for p in params["layers"]:
        h = jax.nn.relu(conv_apply(p, h, cfg.quant, quant_mode=quant_mode,
                                   backend=backend))
    pooled = jnp.mean(h, axis=(1, 2))
    return common.dense_apply(params["head"], pooled,
                              compute_dtype=jnp.float32)

"""sparq-cnn: the paper's conv2d benchmark network as a QAT-able model.

A small channel-first... (TPU-native: NHWC) CNN whose conv layers run:
  * 'qat'    — PACT-clipped activations + LSQ weights, float conv (training);
  * 'packed' — the deployed Sparq path: runtime quantize+P1-pack over
               channels, packed conv2d kernel, affine dequant.

Deployment is two-phase, mirroring the paper's offline planning (§IV):
``conv_prepare`` / ``prepare_packed_params`` quantize + pack each conv
layer's weights ONCE (P1 lanes or bit-dense words) and ``layer_plans`` builds
the per-layer ``KernelPlan``s; the forward pass then only quantizes
activations and dispatches through the prepared plan — no per-call weight
re-packing.  Un-prepared params still work (weights are packed inline), which
keeps QAT-time packed evaluation simple.

This model backs benchmarks/fig4_conv2d.py and fig5_precision_sweep.py and
examples/train_cnn_qat.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing, quant
from repro.core.packing import PackSpec
from repro.kernels import ops
from repro.kernels import plan as plan_lib
from repro.models import common


def conv_init(key, fh, fw, cin, cout, qcfg, dtype=jnp.float32):
    w = jax.random.normal(key, (fh, fw, cin, cout), jnp.float32) \
        / np.sqrt(fh * fw * cin)
    p = {"kernel": w.astype(dtype)}
    if qcfg.enabled:
        p["w_step"] = quant.init_step_from_data(w, qcfg.w_bits, True)
        p["alpha"] = jnp.float32(4.0)   # PACT clip
    return p


def conv_prepare(p, qcfg, *, weight_store: str = "lanes"):
    """Offline per-layer weight preparation (done once, not per forward).

    Quantizes the float kernel to the w_bits lattice and stores it either as
    P1 lanes ('lanes' -> ``w_packed``) or bit-dense int32 words ('dense' ->
    ``w_words``, expanded in the conv kernel prologue).  The float kernel is
    dropped from the prepared layer.
    """
    spec = PackSpec.from_config(qcfg)
    w = p["kernel"].astype(jnp.float32)
    w_scale = p.get("w_step", quant.calibrate_absmax(w, qcfg.w_bits)[0])
    w_zp = qcfg.w_zero_point
    q_w = quant.quantize_affine(w, w_scale, w_zp, qcfg.w_bits)
    out = {"alpha": p.get("alpha", jnp.float32(4.0)),
           "w_scale": jnp.asarray(w_scale, jnp.float32),
           "w_zp": jnp.int32(w_zp)}
    if weight_store == "dense":
        out["w_words"] = ops.dense_store_conv_weights(q_w, qcfg.w_bits)
    elif weight_store == "lanes":
        out["w_packed"] = packing.pack_weights(q_w, spec, axis=2)
    else:
        raise ValueError(weight_store)
    return out


def prepare_packed_params(params, cfg, *, weight_store: str = "lanes"):
    """Convert a trained/QAT param tree for packed serving (weights packed
    once); the float stem and head are untouched (they run un-quantized)."""
    return {"stem": params["stem"],
            "layers": [conv_prepare(p, cfg.quant, weight_store=weight_store)
                       for p in params["layers"]],
            "head": params["head"]}


def layer_plans(params, cfg, x_shape, *, padding: str = "SAME",
                backend: str = "auto", autotune: bool = False):
    """Per-conv-layer KernelPlans for an input [N, H, W, 3] shape.

    SAME padding keeps H, W constant through the stack, so each layer's plan
    differs only in channel counts.  Returns a list aligned with
    params['layers'].

    ``autotune=True`` is the opt-in warm-tune pass (DESIGN.md §14): each
    layer signature missing from the active tuning cache is benchmarked
    once (kernels/autotune.tune_packed_conv2d) before planning, so the
    returned plans are cache-backed; the caller persists the cache
    (``autotune.active_cache().save()``) to tune a deployment once offline.
    """
    n, h, w, _ = x_shape
    spec = PackSpec.from_config(cfg.quant)
    chans = cfg.cnn_channels
    plans = []
    for i, p in enumerate(params["layers"]):
        cin = chans[i - 1] if i > 0 else chans[0]
        if "w_packed" in p:
            w_shape = tuple(p["w_packed"].shape)
            store, k_full = "lanes", None
            cp = w_shape[2]
        elif "w_words" in p:
            w_shape = tuple(p["w_words"].shape)
            store = "dense"
            k_full = cin
            cp = -(-k_full // spec.n_pack)
        else:
            w_shape = tuple(p["kernel"].shape)
            cp = -(-w_shape[2] // spec.n_pack)
            w_shape = w_shape[:2] + (cp,) + w_shape[3:]
            store, k_full = "lanes", None
        if autotune:
            from repro.kernels import autotune as autotune_lib
            autotune_lib.tune_packed_conv2d(
                (n, h, w, cp), w_shape, spec, padding=padding,
                backend=backend, weight_store=store, k_full=k_full)
        plans.append(plan_lib.plan_packed_conv2d(
            (n, h, w, cp), w_shape, spec, padding=padding, backend=backend,
            weight_store=store, k_full=k_full))
    return plans


def conv_apply(p, x, qcfg, *, quant_mode="none", padding="SAME",
               backend="auto", plan=None):
    if quant_mode == "packed" and qcfg.enabled:
        spec = PackSpec.from_config(qcfg)
        prepared = "w_packed" in p or "w_words" in p
        if prepared:
            w_scale, w_zp = p["w_scale"], p["w_zp"]
            wp = p.get("w_packed", p.get("w_words"))
            weight_store = "dense" if "w_words" in p else "lanes"
            fh, fw = wp.shape[:2]
        else:
            # un-prepared fallback (QAT-time eval): pack inline
            w = p["kernel"].astype(jnp.float32)
            w_scale = p.get("w_step", quant.calibrate_absmax(w,
                                                             qcfg.w_bits)[0])
            w_zp = qcfg.w_zero_point
            q_w = quant.quantize_affine(w, w_scale, w_zp, qcfg.w_bits)
            wp = packing.pack_weights(q_w, spec, axis=2)
            weight_store = "lanes"
            fh, fw = p["kernel"].shape[:2]
        # activations: PACT range [0, alpha] -> z=0 lattice
        alpha = p.get("alpha", jnp.float32(4.0))
        a_scale = alpha / qcfg.qmax_a
        xq = quant.quantize_affine(jnp.clip(x, 0.0, alpha), a_scale, 0,
                                   qcfg.a_bits)
        xp = packing.pack_activations(xq, spec, axis=-1)
        k_full = x.shape[-1] if weight_store == "dense" else None
        acc = ops.packed_conv2d(xp, wp, spec, padding=padding,
                                backend=backend, weight_store=weight_store,
                                k_full=k_full, plan=plan).astype(jnp.float32)
        # zero-point correction (z_a = 0): acc - z_w * patch_sums(a)
        ones = jnp.ones((fh, fw, x.shape[-1], 1), jnp.int32)
        psum = jax.lax.conv_general_dilated(
            xq, ones, (1, 1), padding,
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32).astype(jnp.float32)
        return a_scale * w_scale * (acc - w_zp * psum)
    w = p["kernel"].astype(jnp.float32)
    xx = x.astype(jnp.float32)
    if quant_mode == "qat" and qcfg.enabled:
        w = quant.lsq_fake_quant(w, p["w_step"], qcfg.w_bits, True)
        alpha = p["alpha"]
        xc = quant.pact_clip(xx, alpha, qcfg.a_bits)
        xx = quant.fake_quant(xc, alpha / qcfg.qmax_a, jnp.float32(0.0),
                              qcfg.a_bits)
    return jax.lax.conv_general_dilated(
        xx, w, (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"))


def init_params(key, cfg):
    chans = cfg.cnn_channels
    ks = jax.random.split(key, len(chans) + 1)
    layers = []
    cin = chans[0]  # input is pre-embedded to chans[0] by a stem below
    stem = conv_init(ks[0], 3, 3, 3, chans[0], cfg.quant)
    for i, cout in enumerate(chans):
        layers.append(conv_init(ks[i], cfg.cnn_kernel, cfg.cnn_kernel,
                                cin, cout, cfg.quant))
        cin = cout
    head = common.dense_init(ks[-1], cin, cfg.cnn_num_classes)
    return {"stem": stem, "layers": layers, "head": head}


def forward(params, cfg, x, *, quant_mode="none", backend="auto",
            plans=None):
    """x: [N, H, W, 3] image -> logits [N, classes].

    ``plans`` (from ``layer_plans``) routes each conv through its prebuilt
    KernelPlan; without it, plans are looked up from the memoized planners.
    """
    h = jax.nn.relu(conv_apply(params["stem"], x, cfg.quant,
                               quant_mode="none"))
    for i, p in enumerate(params["layers"]):
        plan = plans[i] if plans is not None else None
        h = jax.nn.relu(conv_apply(p, h, cfg.quant, quant_mode=quant_mode,
                                   backend=backend, plan=plan))
    pooled = jnp.mean(h, axis=(1, 2))
    return common.dense_apply(params["head"], pooled,
                              compute_dtype=jnp.float32)

"""Functional NN substrate: Dense (float / QAT / packed-integer), norms,
embeddings, RoPE (incl. M-RoPE).

Parameters are plain nested dicts; every layer is an (init, apply) pair.
``quant_mode``:
  'none'   — float path.
  'qat'    — LSQ fake-quant on weights+activations (training; STE grads).
  'packed' — deployed Sparq path: runtime activation quantize+pack, packed
             integer matmul, affine dequant.  Params must have been converted
             with ``pack_dense_params``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant
from repro.core.packing import PackSpec
from repro.core.quant import QuantConfig
from repro.kernels import ops


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


# ---------------------------------------------------------------------------
# Dense
# ---------------------------------------------------------------------------

def dense_init(key, d_in, d_out, *, use_bias=False, dtype=jnp.float32,
               quantized=False, qcfg: QuantConfig | None = None, scale=None):
    std = scale if scale is not None else 1.0 / np.sqrt(d_in)
    kernel = jax.random.normal(key, (d_in, d_out), jnp.float32) * std
    p = {"kernel": kernel.astype(dtype)}
    if use_bias:
        p["bias"] = jnp.zeros((d_out,), dtype)
    if quantized and qcfg is not None and qcfg.enabled:
        p["w_step"] = quant.init_step_from_data(kernel, qcfg.w_bits, True)
        p["a_step"] = jnp.asarray(1.0 / np.sqrt(qcfg.qmax_a), jnp.float32)
    return p


def dense_layer_spec(k: int, n: int, qcfg: QuantConfig, *,
                     weight_store: str = "lanes",
                     w_packed=None) -> PackSpec:
    """The per-layer *chosen* lane layout for a [k, n] Dense (DESIGN.md §16).

    Resolves through the active autotune layout cache (autotune.
    matmul_layout_for), defaulting to the config-global spec on a miss, so
    pack time, plan time and dispatch time all agree on one layout.  With
    the lanes store the packed leaf (``w_packed``) is evidence of the layout
    the stored bytes actually use: if the cache changed since pack time and
    the chosen layout no longer matches the leaf's dtype/shape, fall back to
    the config spec rather than misread the bytes.  (Bit-dense words are
    layout-agnostic at rest, so the dense store never needs this guard.)
    """
    from repro.kernels import autotune

    base = PackSpec.from_config(qcfg)
    spec = autotune.matmul_layout_for(k, n, base, backend="auto",
                                      weight_store=weight_store)
    if weight_store == "lanes" and w_packed is not None and spec != base:
        if (w_packed.dtype != spec.lane_dtype
                or w_packed.shape[0] != -(-k // spec.n_pack)):
            return base
    return spec


def dense_apply(p, x, *, qcfg: QuantConfig | None = None,
                quant_mode: str = "none", compute_dtype=jnp.bfloat16):
    """y = x @ kernel (+ bias), under the selected quantization mode."""
    quantized = qcfg is not None and qcfg.enabled and "w_step" in p \
        or (qcfg is not None and qcfg.enabled and "w_packed" in p)
    if quant_mode == "packed" and ("w_packed" in p or "w_dense" in p):
        dense = "w_dense" in p
        w = p["w_dense"] if dense else p["w_packed"]
        spec = dense_layer_spec(
            int(x.shape[-1]), int(w.shape[-1]), qcfg,
            weight_store="dense" if dense else "lanes",
            w_packed=None if dense else w)
        return ops.quantized_linear(
            x.astype(jnp.float32), w,
            p["col_sums"], p["a_scale"], p["a_zp"], p["w_scale"], p["w_zp"],
            spec, bias=p.get("bias"), backend="auto",
            weight_store="dense" if dense else "lanes",
            out_dtype=compute_dtype)
    kernel = p["kernel"].astype(compute_dtype)
    if quant_mode == "qat" and quantized and "w_step" in p:
        # weights fake-quant in f32 (few, precision-sensitive); activations
        # fake-quant in compute dtype — the lattice (<= 2^bits) is exactly
        # representable in bf16, and this halves the activation temp/traffic
        kernel = quant.lsq_fake_quant(
            p["kernel"].astype(jnp.float32), p["w_step"], qcfg.w_bits,
            True).astype(compute_dtype)
        x = quant.lsq_fake_quant(
            x.astype(compute_dtype), p["a_step"].astype(compute_dtype),
            qcfg.a_bits, True)
    y = jnp.dot(x.astype(compute_dtype), kernel)
    if "bias" in p:
        y = y + p["bias"].astype(compute_dtype)
    return y


def pack_dense_params(p, qcfg: QuantConfig, *, dense_store: bool = False,
                      spec: PackSpec | None = None):
    """Offline conversion QAT/float Dense params -> deployed packed params.

    ``dense_store=True`` keeps the weight bit-dense (int32 words, true
    w_bits/value HBM footprint; key ``w_dense``) instead of as P1 lanes —
    the decode memory-bound path; lanes are recovered at use.

    The lane layout is the per-layer chosen spec (``dense_layer_spec``:
    active layout cache, config default on miss) unless pinned via ``spec``
    — weights pack once offline, so the layout decision happens here and
    dispatch resolves the same choice.
    """
    kernel = p["kernel"].astype(jnp.float32)
    store = "dense" if dense_store else "lanes"
    if spec is None:
        spec = dense_layer_spec(int(kernel.shape[0]), int(kernel.shape[1]),
                                qcfg, weight_store=store)
    w_scale = p.get("w_step")
    if w_scale is None:
        w_scale, _ = quant.calibrate_absmax(kernel, qcfg.w_bits)
    w_zp = jnp.int32(qcfg.w_zero_point)
    w_packed, col_sums = ops.prepare_weights(kernel, w_scale, w_zp, spec,
                                             weight_store=store)
    a_scale = p.get("a_step", jnp.float32(1.0 / np.sqrt(qcfg.qmax_a)))
    a_zp = jnp.int32((qcfg.qmax_a + 1) // 2)
    # Packing rounds K up (words and lanes both); record the exact K so
    # offline plan building and layout resolution key the same (k, n) the
    # dispatch path derives from x.shape.
    out = {"w_dense" if dense_store else "w_packed": w_packed,
           "col_sums": col_sums,
           "w_scale": jnp.asarray(w_scale, jnp.float32), "w_zp": w_zp,
           "a_scale": jnp.asarray(a_scale, jnp.float32), "a_zp": a_zp,
           "k_full": int(kernel.shape[0])}
    if "bias" in p:
        out["bias"] = p["bias"]
    return out


# ---------------------------------------------------------------------------
# Norms & embedding
# ---------------------------------------------------------------------------

def rmsnorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm_init(d, dtype=jnp.float32):
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(p, x, eps=1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"] + p["bias"]).astype(dt)


def embedding_init(key, vocab, d, dtype=jnp.float32):
    return {"table": (jax.random.normal(key, (vocab, d), jnp.float32)
                      * 0.02).astype(dtype)}


def embedding_apply(p, tokens, compute_dtype=jnp.bfloat16):
    """Embedding lookup.

    Under a production mesh the table is vocab-sharded over 'model'; a plain
    gather there makes XLA SPMD replicate the table per use (and hits a
    partitioner verifier bug inside scan bodies), so we do the standard
    sharded-vocab lookup manually: shard_map -> masked local gather -> psum.
    Outside a mesh this is a plain take().
    """
    from repro.parallel import sharding as shlib
    mesh = shlib._ACTIVE_MESH[-1]
    table = p["table"]
    if mesh is None or "model" not in mesh.shape \
            or table.shape[0] % mesh.shape["model"] != 0:
        return jnp.take(table, tokens, axis=0).astype(compute_dtype)

    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import shard_map

    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    bspec = dp if dp and tokens.shape[0] % shlib._axis_size(mesh, dp) == 0 \
        else None

    def local(tab, tok):
        idx = jax.lax.axis_index("model")
        vloc = tab.shape[0]
        rel = tok - idx * vloc
        ok = (rel >= 0) & (rel < vloc)
        safe = jnp.clip(rel, 0, vloc - 1)
        emb = jnp.take(tab, safe, axis=0).astype(compute_dtype)
        emb = emb * ok[..., None].astype(compute_dtype)
        return jax.lax.psum(emb, "model")

    return shard_map(
        local, mesh=mesh,
        in_specs=(P("model", None), P(bspec, None)),
        out_specs=P(bspec, None, None),
        check_vma=False)(table, tokens)


def embedding_attend(p, x):
    """Tied LM head: x [.., d] @ table.T -> [.., vocab]."""
    return jnp.dot(x, p["table"].astype(x.dtype).T)


# ---------------------------------------------------------------------------
# RoPE (standard + M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta=10000.0):
    """x: [B, S, H, hd]; positions: [B, S] int."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # [hd/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [B,S,hd/2]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, sections, theta=10000.0):
    """Multimodal RoPE (qwen2-vl §2): positions3 [3, B, S] = (t, h, w) ids;
    frequency channels are split between the three components."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = rope_freqs(hd, theta)                      # [half]
    sec = np.cumsum((0,) + tuple(sections))
    assert sec[-1] == half, (sections, half)
    comp = np.zeros((half,), np.int32)
    for i in range(3):
        comp[sec[i]:sec[i + 1]] = i
    comp = jnp.asarray(comp)
    pos = jnp.take(positions3, comp, axis=0)           # [half, B, S]
    angles = jnp.moveaxis(pos, 0, -1).astype(jnp.float32) * freqs  # [B,S,half]
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)

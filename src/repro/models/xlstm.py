"""xLSTM blocks: chunkwise-parallel mLSTM + recurrent sLSTM.

mLSTM (matrix memory, exponential input gate, sigmoid forget gate) is
implemented in the *chunkwise-parallel* form: within a chunk of length L the
recurrence is evaluated as a masked, gate-weighted attention-like product
(MXU matmuls); across chunks a stabilized (log-space, all exponents <= 0)
matrix state (C, n, m) is carried by a scan.  This is the TPU-native
realization — the sequential form would leave the MXU idle and store an
O(S) trail of d_head^2 states (DESIGN.md §2 hardware-adaptation notes).

sLSTM (scalar memory, recurrent gate feedback) is inherently sequential; it
runs as a chunk-checkpointed lax.scan.

Projections are quantizable Dense layers; the recurrences run fp32.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common
from repro.models.common import dense_apply, dense_init


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    inner = int(cfg.mlstm_proj_factor * d)
    nh = cfg.num_heads
    ks = jax.random.split(key, 8)
    p = {
        "up": dense_init(ks[0], d, 2 * inner, dtype=dtype, quantized=True,
                         qcfg=cfg.quant),
        "q": dense_init(ks[1], inner, inner, dtype=dtype, quantized=True,
                        qcfg=cfg.quant),
        "k": dense_init(ks[2], inner, inner, dtype=dtype, quantized=True,
                        qcfg=cfg.quant),
        "v": dense_init(ks[3], inner, inner, dtype=dtype, quantized=True,
                        qcfg=cfg.quant),
        "if_gate": dense_init(ks[4], inner, 2 * nh, use_bias=True,
                              dtype=dtype),
        "norm": common.rmsnorm_init(inner, dtype),
        "down": dense_init(ks[5], inner, d, dtype=dtype, quantized=True,
                           qcfg=cfg.quant),
    }
    # forget-gate bias init: strongly positive => long memory at init.
    p["if_gate"]["bias"] = p["if_gate"]["bias"].at[nh:].set(3.0)
    return p


def init_mlstm_cache(cfg, batch, dtype=jnp.float32):
    inner = int(cfg.mlstm_proj_factor * cfg.d_model)
    nh = cfg.num_heads
    hd = inner // nh
    return {
        "C": jnp.zeros((batch, nh, hd, hd), dtype),
        "n": jnp.zeros((batch, nh, hd), dtype),
        "m": jnp.full((batch, nh), -1e30, dtype),
    }


def _mlstm_chunk(q, k, v, i_raw, g_log, state):
    """One chunk of the stabilized chunkwise mLSTM.

    q,k,v: [B, NH, L, hd] fp32; i_raw,g_log: [B, NH, L]; state (C, n, m)
    stored *descaled* by exp(m).  Returns (h [B,NH,L,hd], new_state).
    All exponents below are <= 0 by construction.
    """
    c_prev, n_prev, m_prev = state
    hd = q.shape[-1]
    big = q.shape[2]
    gc = jnp.cumsum(g_log, axis=-1)                      # G_t
    s_run = jax.lax.cummax(i_raw - gc, axis=i_raw.ndim - 1)  # s_t
    m_eff = jnp.maximum(s_run, m_prev[..., None])        # M_t - G_t
    m_t = gc + m_eff

    # intra-chunk gate-weighted scores: A[t, tau] = exp(i_tau - G_tau - m_eff_t)
    log_a = (i_raw - gc)[..., None, :] - m_eff[..., :, None]
    mask = jnp.tril(jnp.ones((big, big), bool))
    a = jnp.where(mask, jnp.exp(log_a), 0.0)             # [B,NH,L,L]

    scale = hd ** -0.5
    scores = jnp.einsum("bhtd,bhsd->bhts", q * scale, k)
    h_num = jnp.einsum("bhts,bhsd->bhtd", a * scores, v)
    n_t = jnp.einsum("bhts,bhsd->bhtd", a, k)

    # inter-chunk contribution, weight b_t = exp(m_prev - max(s_t, m_prev))
    b = jnp.exp(m_prev[..., None] - m_eff)               # [B,NH,L]
    h_num = h_num + b[..., None] * jnp.einsum("bhtd,bhde->bhte",
                                              q * scale, c_prev)
    n_t = n_t + b[..., None] * n_prev[..., None, :]

    qn = jnp.einsum("bhtd,bhtd->bht", q * scale, n_t)
    denom = jnp.maximum(jnp.abs(qn), jnp.exp(-m_t))
    h = h_num / denom[..., None]

    # ---- state update at chunk end ----
    g_total = gc[..., -1]                                # G_L
    m_new = g_total + jnp.maximum(s_run[..., -1], m_prev)
    decay = jnp.exp(g_total + m_prev - m_new)            # <= 1
    w_kv = jnp.exp((g_total[..., None] - gc) + i_raw - m_new[..., None])
    c_new = (decay[..., None, None] * c_prev
             + jnp.einsum("bhs,bhsd,bhse->bhde", w_kv, k, v))
    n_new = decay[..., None] * n_prev + jnp.einsum("bhs,bhsd->bhd", w_kv, k)
    return h, (c_new, n_new, m_new)


def mlstm_apply(p, cfg, x, *, quant_mode="none", cache=None,
                cache_index=None, cache_valid=None, chunk=128):
    """x: [B, S, d] -> (y, new_cache).

    The cached path continues the chunkwise recurrence from (C, n, m) for
    any window length S.  ``cache_valid`` [B] gates ragged windows: pad
    tokens past each row's valid prefix are turned into identity updates
    (forget gate 1, input gate 0 — the same trick the prefill pad uses).
    """
    b, s, d = x.shape
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    inner = int(cfg.mlstm_proj_factor * d)
    nh = cfg.num_heads
    hd = inner // nh

    up = dense_apply(p["up"], x, **qm)
    xm, z = jnp.split(up, 2, axis=-1)

    def heads(t):
        return t.reshape(b, s, nh, hd).transpose(0, 2, 1, 3) \
            .astype(jnp.float32)

    q = heads(dense_apply(p["q"], xm, **qm))
    k = heads(dense_apply(p["k"], xm, **qm))
    v = heads(dense_apply(p["v"], xm, **qm))
    gates = dense_apply(p["if_gate"], xm,
                        compute_dtype=jnp.float32)       # [B,S,2nh]
    i_raw = gates[..., :nh].transpose(0, 2, 1)           # [B,NH,S]
    g_log = jax.nn.log_sigmoid(gates[..., nh:]).transpose(0, 2, 1)

    if cache is not None and cache_index is not None:
        if cache_valid is not None:
            inval = (jnp.arange(s)[None, None, :]
                     >= jnp.asarray(cache_valid, jnp.int32)[:, None, None])
            i_raw = jnp.where(inval, -1e30, i_raw)
            g_log = jnp.where(inval, 0.0, g_log)
            # belt-and-braces: zero pad k/v so even the all-invalid fresh-
            # state corner (m_prev = -inf -> w_kv = 1) adds nothing to C/n
            k = jnp.where(inval[..., None], 0.0, k)
            v = jnp.where(inval[..., None], 0.0, v)
        state = (cache["C"].astype(jnp.float32),
                 cache["n"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        h, (c2, n2, m2) = _mlstm_chunk(q, k, v, i_raw, g_log, state)
        new_cache = {"C": c2.astype(cache["C"].dtype),
                     "n": n2.astype(cache["n"].dtype),
                     "m": m2.astype(cache["m"].dtype)}
    else:
        l_chunk = min(chunk, s)
        pad = (-s) % l_chunk
        if pad:
            q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                       for t in (q, k, v))
            i_raw = jnp.pad(i_raw, ((0, 0), (0, 0), (0, pad)), constant_values=-1e30)
            g_log = jnp.pad(g_log, ((0, 0), (0, 0), (0, pad)))
        nchunks = q.shape[2] // l_chunk

        def split(t, extra=()):
            shp = (b, nh, nchunks, l_chunk) + tuple(extra)
            return jnp.moveaxis(t.reshape(shp), 2, 0)

        qs, ks_, vs = split(q, (hd,)), split(k, (hd,)), split(v, (hd,))
        is_, gs = split(i_raw), split(g_log)
        state0 = (jnp.zeros((b, nh, hd, hd), jnp.float32),
                  jnp.zeros((b, nh, hd), jnp.float32),
                  jnp.full((b, nh), -1e30, jnp.float32))

        def body(st, inp):
            h, st2 = _mlstm_chunk(*inp, st)
            return st2, h

        last, hs = jax.lax.scan(body, state0, (qs, ks_, vs, is_, gs))
        h = jnp.moveaxis(hs, 0, 2).reshape(b, nh, nchunks * l_chunk, hd)
        h = h[:, :, :s]
        new_cache = None
        if cache is not None:
            new_cache = {"C": last[0].astype(cache["C"].dtype),
                         "n": last[1].astype(cache["n"].dtype),
                         "m": last[2].astype(cache["m"].dtype)}

    h = h.transpose(0, 2, 1, 3).reshape(b, -1, inner)[:, :s]
    h = common.rmsnorm_apply(p["norm"], h.astype(cd), cfg.norm_eps)
    h = h * jax.nn.silu(z.astype(cd))
    return dense_apply(p["down"], h, **qm), new_cache


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_init(key, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    ks = jax.random.split(key, 4)
    dff = int(d * 4 / 3)
    p = {
        # gate path feeds the recurrence: keep fp (DESIGN.md §5)
        "w_gates": dense_init(ks[0], d, 4 * d, use_bias=True, dtype=dtype),
        # block-diagonal (per-head) recurrent weights
        "r_gates": (jax.random.normal(ks[1], (nh, hd, 4 * hd), jnp.float32)
                    / np.sqrt(hd)).astype(dtype),
        "norm": common.rmsnorm_init(d, dtype),
        "ffn_up": dense_init(ks[2], d, 2 * dff, dtype=dtype, quantized=True,
                             qcfg=cfg.quant),
        "ffn_down": dense_init(ks[3], dff, d, dtype=dtype, quantized=True,
                               qcfg=cfg.quant),
    }
    b = p["w_gates"]["bias"]
    p["w_gates"]["bias"] = b.at[2 * d:3 * d].set(3.0)   # forget bias
    return p


def init_slstm_cache(cfg, batch, dtype=jnp.float32):
    d = cfg.d_model
    nh = cfg.num_heads
    hd = d // nh
    z = lambda: jnp.zeros((batch, nh, hd), dtype)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, nh, hd), -1e30, dtype)}


def _slstm_step(p_r, state, wx, nh, hd):
    """wx: [B, 4d] precomputed input contribution; state dict of [B,nh,hd]."""
    c, n, h, m = state
    rx = jnp.einsum("bhd,hde->bhe", h, p_r)              # [B,nh,4hd]
    gates = wx.reshape(wx.shape[0], nh, 4 * hd) + rx
    z_in, i_raw, f_raw, o_raw = jnp.split(gates, 4, axis=-1)
    z_t = jnp.tanh(z_in)
    o_t = jax.nn.sigmoid(o_raw)
    f_log = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(f_log + m, i_raw)
    i_p = jnp.exp(i_raw - m_new)
    f_p = jnp.exp(f_log + m - m_new)
    c_new = f_p * c + i_p * z_t
    n_new = jnp.maximum(f_p * n + i_p, jnp.exp(-m_new))
    h_new = o_t * c_new / n_new
    return (c_new, n_new, h_new, m_new)


def slstm_apply(p, cfg, x, *, quant_mode="none", cache=None,
                cache_index=None, cache_valid=None, chunk=256):
    """x: [B, S, d] -> (y, new_cache).  Sequential scan (chunk-checkpointed).

    The cached path scans any window length S from the cached state;
    ``cache_valid`` [B] gates ragged windows (pad tokens past each row's
    valid prefix leave that row's state untouched).
    """
    b, s, d = x.shape
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    nh = cfg.num_heads
    hd = d // nh
    wx = dense_apply(p["w_gates"], x, compute_dtype=jnp.float32)
    r = p["r_gates"].astype(jnp.float32)

    if cache is not None and cache_index is not None:
        state = (cache["c"].astype(jnp.float32),
                 cache["n"].astype(jnp.float32),
                 cache["h"].astype(jnp.float32),
                 cache["m"].astype(jnp.float32))
        vlen = (jnp.full((b,), s, jnp.int32) if cache_valid is None
                else jnp.asarray(cache_valid, jnp.int32))

        def dstep(st, inp):
            wxt, keep = inp
            st2 = _slstm_step(r, st, wxt, nh, hd)
            st2 = tuple(jnp.where(keep[:, None, None], a2, a1)
                        for a1, a2 in zip(st, st2))
            return st2, st2[2]

        keep = (jnp.arange(s)[None, :] < vlen[:, None]).T   # [S, B]
        state, hs = jax.lax.scan(dstep, state,
                                 (jnp.moveaxis(wx, 1, 0), keep))
        h_seq = jnp.moveaxis(hs, 0, 1)                      # [B, S, nh, hd]
        new_cache = {k2: v2.astype(cache[k2].dtype) for k2, v2 in
                     zip(("c", "n", "h", "m"), state)}
    else:
        state = (jnp.zeros((b, nh, hd), jnp.float32),
                 jnp.zeros((b, nh, hd), jnp.float32),
                 jnp.zeros((b, nh, hd), jnp.float32),
                 jnp.full((b, nh, hd), -1e30, jnp.float32))

        @jax.checkpoint
        def chunk_body(st, wxc):
            def step(st2, wxt):
                st3 = _slstm_step(r, st2, wxt, nh, hd)
                return st3, st3[2]
            return jax.lax.scan(step, st, wxc)

        l_chunk = min(chunk, s)
        pad = (-s) % l_chunk
        wxp = jnp.pad(wx, ((0, 0), (0, pad), (0, 0)))
        nchunks = wxp.shape[1] // l_chunk
        # [nchunks, l_chunk, B, 4d] — outer scan over chunks, inner over time
        wxc = wxp.reshape(b, nchunks, l_chunk, -1).transpose(1, 2, 0, 3)
        state, hs = jax.lax.scan(chunk_body, state, wxc)
        hs = hs.reshape(nchunks * l_chunk, b, nh, hd)
        h_seq = jnp.moveaxis(hs, 0, 1)[:, :s]
        new_cache = None
        if cache is not None:
            new_cache = {k2: v2.astype(cache[k2].dtype) for k2, v2 in
                         zip(("c", "n", "h", "m"), state)}

    h = h_seq.reshape(b, -1, d).astype(cd)
    h = common.rmsnorm_apply(p["norm"], h, cfg.norm_eps)
    # post-sLSTM gated FFN (proj factor 4/3)
    upg = dense_apply(p["ffn_up"], h, **qm)
    u, g = jnp.split(upg, 2, axis=-1)
    y = dense_apply(p["ffn_down"], u * jax.nn.silu(g), **qm)
    return y, new_cache

"""Model assembly: heterogeneous decoder stacks (attn / mamba / mLSTM /
sLSTM blocks, MoE or dense FFN halves), encoder-decoder, modality-frontend
stubs, LM head and loss.

Batch protocols (matching launch/input_specs):
  dense/moe/ssm/hybrid : {"tokens": [B,S], "labels": [B,S]}
  vlm (qwen2-vl)       : + {"embeds": [B,S_img,fd], "positions3": [3,B,S]}
  audio enc-dec        : {"enc_embeds": [B,S_enc,fd], "tokens": [B,S_dec], ...}
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention, common, mamba, mlp, moe, xlstm
from repro.parallel.sharding import constrain
from repro.models.common import dense_apply, dense_init


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------

def block_init(key, cfg, i, *, cross=False, dtype=jnp.float32):
    kind = cfg.layer_kind(i)
    ks = jax.random.split(key, 6)
    p = {}
    if kind == "attn":
        p["norm1"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["attn"] = attention.attention_init(ks[0], cfg, dtype=dtype)
    elif kind == "mamba":
        p["norm1"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["mamba"] = mamba.mamba_init(ks[0], cfg, dtype=dtype)
    elif kind == "mlstm":
        p["norm1"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["mlstm"] = xlstm.mlstm_init(ks[0], cfg, dtype=dtype)
    elif kind == "slstm":
        p["norm1"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["slstm"] = xlstm.slstm_init(ks[0], cfg, dtype=dtype)
    if cross:
        p["norm_x"] = common.rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attention.attention_init(ks[1], cfg, cross=True,
                                              dtype=dtype)
    # FFN half (attn/mamba families; xLSTM blocks are single-residual)
    if kind in ("attn", "mamba") and (cfg.d_ff or cfg.layer_is_moe(i)):
        p["norm2"] = common.rmsnorm_init(cfg.d_model, dtype)
        if cfg.layer_is_moe(i):
            p["moe"] = moe.moe_init(ks[2], cfg, dtype=dtype)
        else:
            p["mlp"] = mlp.mlp_init(ks[2], cfg, dtype=dtype)
    return p


def block_apply(p, cfg, x, *, kind="attn", positions, quant_mode="none",
                cache=None, cache_index=None, cache_valid=None, causal=True,
                positions3=None, enc_kv=None, moe_path="einsum",
                kv_shard_axis=None, block_tables=None):
    """One residual block.  Returns (x, new_cache, aux_loss).

    ``cache_index`` may be a scalar (lockstep decode) or a [B] vector of
    per-slot write offsets; ``cache_valid`` [B] counts each row's valid-
    prefix tokens for ragged windows (DESIGN.md §12).  ``kv_shard_axis``
    names the mesh axis a serving ShardPlan sharded the KV-cache kv-head
    axis over (DESIGN.md §15); None = unsharded serving.  ``block_tables``
    [B, n_pages] selects the paged attention cache path (pool + per-slot
    block table, DESIGN.md §18); recurrent sub-caches stay per-slot.
    """
    aux = 0.0
    new_cache = dict(cache) if cache is not None else None
    h = common.rmsnorm_apply(p["norm1"], x, cfg.norm_eps)
    if kind == "attn":
        sub = cache.get("attn") if cache else None
        out, sub2 = attention.attention_apply(
            p["attn"], cfg, h, positions=positions, quant_mode=quant_mode,
            cache=sub, cache_index=cache_index, cache_valid=cache_valid,
            causal=causal, positions3=positions3,
            kv_shard_axis=kv_shard_axis, block_tables=block_tables)
        if new_cache is not None and sub2 is not None:
            new_cache["attn"] = sub2
    elif kind == "mamba":
        sub = cache.get("mamba") if cache else None
        out, sub2 = mamba.mamba_apply(
            p["mamba"], cfg, h, quant_mode=quant_mode, cache=sub,
            cache_index=cache_index, cache_valid=cache_valid)
        if new_cache is not None and sub2 is not None:
            new_cache["mamba"] = sub2
    elif kind == "mlstm":
        sub = cache.get("mlstm") if cache else None
        out, sub2 = xlstm.mlstm_apply(
            p["mlstm"], cfg, h, quant_mode=quant_mode, cache=sub,
            cache_index=cache_index, cache_valid=cache_valid)
        if new_cache is not None and sub2 is not None:
            new_cache["mlstm"] = sub2
    elif kind == "slstm":
        sub = cache.get("slstm") if cache else None
        out, sub2 = xlstm.slstm_apply(
            p["slstm"], cfg, h, quant_mode=quant_mode, cache=sub,
            cache_index=cache_index, cache_valid=cache_valid)
        if new_cache is not None and sub2 is not None:
            new_cache["slstm"] = sub2
    else:
        raise ValueError(kind)
    x = x + out

    if "cross" in p and enc_kv is not None:
        h = common.rmsnorm_apply(p["norm_x"], x, cfg.norm_eps)
        out, _ = attention.attention_apply(
            p["cross"], cfg, h, positions=positions, quant_mode=quant_mode,
            cross_kv=enc_kv, causal=False)
        x = x + out

    if "moe" in p:
        h = common.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        out, aux = moe.moe_apply(p["moe"], cfg, h, quant_mode=quant_mode,
                                 path=moe_path)
        x = x + out
    elif "mlp" in p:
        h = common.rmsnorm_apply(p["norm2"], x, cfg.norm_eps)
        x = x + mlp.mlp_apply(p["mlp"], cfg, h, quant_mode=quant_mode)
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(key, cfg):
    dtype = common.dtype_of(cfg.param_dtype)
    keys = jax.random.split(key, cfg.num_layers + cfg.encoder_layers + 4)
    p = {"embed": common.embedding_init(keys[0], cfg.padded_vocab,
                                        cfg.d_model, dtype)}
    cross = cfg.is_encoder_decoder
    p["layers"] = [
        block_init(keys[1 + i], cfg, i, cross=cross, dtype=dtype)
        for i in range(cfg.num_layers)]
    p["final_norm"] = common.rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        p["lm_head"] = dense_init(
            keys[cfg.num_layers + 1], cfg.d_model, cfg.padded_vocab,
            dtype=dtype, quantized=cfg.quant.quantize_lm_head,
            qcfg=cfg.quant)
    if cfg.is_encoder_decoder:
        enc_cfg = cfg  # same dims; encoder is non-causal full attention
        p["encoder"] = {
            "layers": [block_init(keys[cfg.num_layers + 2 + i], enc_cfg, i,
                                  dtype=dtype)
                       for i in range(cfg.encoder_layers)],
            "final_norm": common.rmsnorm_init(cfg.d_model, dtype),
        }
    if cfg.frontend != "none":
        p["frontend_proj"] = dense_init(
            keys[-1], cfg.frontend_dim, cfg.d_model, dtype=dtype)
    return p


def encode(params, cfg, enc_embeds, *, quant_mode="none"):
    """Encoder over stub modality embeddings -> memory states [B,S,d]."""
    cd = common.dtype_of(cfg.compute_dtype)
    x = dense_apply(params["frontend_proj"], enc_embeds.astype(cd),
                    compute_dtype=cd)
    pos = jnp.arange(x.shape[1])[None, :]
    pos = jnp.broadcast_to(pos, x.shape[:2])
    for blk in params["encoder"]["layers"]:
        x, _, _ = block_apply(blk, cfg, x, kind="attn", positions=pos,
                              quant_mode=quant_mode, causal=False)
    return common.rmsnorm_apply(params["encoder"]["final_norm"], x,
                                cfg.norm_eps)


def _decoder_inputs(params, cfg, batch):
    """Token (+ modality prefix) embeddings and positions."""
    cd = common.dtype_of(cfg.compute_dtype)
    x = common.embedding_apply(params["embed"], batch["tokens"], cd)
    if cfg.frontend == "vision" and "embeds" in batch:
        prefix = dense_apply(params["frontend_proj"],
                             batch["embeds"].astype(cd), compute_dtype=cd)
        x = jnp.concatenate([prefix, x], axis=1)
    b, s = x.shape[0], x.shape[1]
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s)[None, :], (b, s))
    return x, positions


def forward(params, cfg, batch, *, quant_mode="none", caches=None,
            cache_index=None, cache_valid=None, enc_out=None, remat=False,
            moe_path="einsum", kv_shard_axis=None, block_tables=None):
    """Full forward.  Returns (logits, aux_loss, new_caches).

    ``cache_index`` scalar = lockstep decode; [B] vector = per-slot cache
    write offsets (ragged continuous batching).  ``cache_valid`` [B] is the
    per-row valid-prefix length of the current window (chunked prefill).
    ``kv_shard_axis`` (serving TP, DESIGN.md §15) pins attention's KV-cache
    quantize/pack/write to the kv-head shard axis so GSPMD never reshards
    the cache between steps.  ``block_tables`` [B, n_pages] routes every
    attention layer through the paged cache pool (DESIGN.md §18); the one
    table indexes all layers' pools.
    """
    import os
    seq_ax = "model" if os.environ.get("REPRO_SEQ_ACT", "0") == "1" \
        else None
    x, positions = _decoder_inputs(params, cfg, batch)
    x = constrain(x, "dp", seq_ax, None)
    positions3 = batch.get("positions3")

    enc_kv = None
    if cfg.is_encoder_decoder:
        if enc_out is None and "enc_embeds" in batch:
            enc_out = encode(params, cfg, batch["enc_embeds"],
                             quant_mode=quant_mode)

    aux_total = 0.0
    new_caches = [] if caches is not None else None

    def run_block(blk, x, sub, kind):
        return block_apply(
            blk, cfg, x, kind=kind, positions=positions,
            quant_mode=quant_mode, cache=sub, cache_index=cache_index,
            cache_valid=cache_valid, causal=True, positions3=positions3,
            enc_kv=enc_kv, moe_path=moe_path, kv_shard_axis=kv_shard_axis,
            block_tables=block_tables)

    for li, blk in enumerate(params["layers"]):
        if cfg.is_encoder_decoder:
            cached_kv = caches[li].get("cross_kv") if caches is not None \
                else None
            if cached_kv is not None:
                enc_kv = cached_kv
            elif enc_out is not None:
                enc_kv = attention.precompute_cross_kv(
                    blk["cross"], cfg, enc_out, quant_mode=quant_mode)
        sub = caches[li] if caches is not None else None
        fn = jax.checkpoint(run_block, static_argnums=(3,)) if remat \
            else run_block
        x, sub2, aux = fn(blk, x, sub, cfg.layer_kind(li))
        # Megatron-SP (REPRO_SEQ_ACT=1): residual stream sequence-sharded
        # over the TP axis between blocks -> the TP all-reduce becomes a
        # reduce-scatter + all-gather pair (half the wire bytes) and norms
        # run seq-sharded (§Perf cell B)
        x = constrain(x, "dp", seq_ax, None)
        aux_total = aux_total + aux
        if new_caches is not None:
            if cfg.is_encoder_decoder and enc_kv is not None:
                sub2 = dict(sub2 or {})
                sub2["cross_kv"] = enc_kv
            new_caches.append(sub2)

    x = common.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps)
    if cfg.tie_embeddings:
        logits = common.embedding_attend(params["embed"], x)
    else:
        logits = dense_apply(
            params["lm_head"], x,
            qcfg=cfg.quant if cfg.quant.quantize_lm_head else None,
            quant_mode=quant_mode,
            compute_dtype=common.dtype_of(cfg.compute_dtype))
    logits = constrain(logits, "dp", None, "model")
    if cfg.padded_vocab != cfg.vocab_size:
        # additive pad bias (fuses into the head matmul epilogue) instead of
        # a where() over an f32 copy — §Perf cell-A iteration 4
        pad_bias = jnp.where(
            jnp.arange(cfg.padded_vocab) >= cfg.vocab_size, -1e30,
            0.0).astype(logits.dtype)
        logits = logits + pad_bias
    return logits, aux_total, new_caches


def init_caches(cfg, batch_size, max_len, dtype=jnp.bfloat16, *,
                page_size=None, num_pages=None):
    """Per-layer decode caches sized for max_len (ring-bounded for SWA).

    With ``page_size``/``num_pages`` the attention caches are paged pools
    ([num_pages, page_size, KVH, ...], one shared page-id space across
    layers, DESIGN.md §18) instead of slot-contiguous rings; recurrent
    sub-caches (mamba/xLSTM) keep their ``batch_size`` slot rows either
    way — only attention KV pages."""
    paged = num_pages is not None
    if paged and page_size is None:
        raise ValueError("num_pages requires page_size")
    caches = []
    for i in range(cfg.num_layers):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            c = {"attn": attention.init_paged_kv_cache(
                cfg, num_pages, page_size, dtype) if paged
                else attention.init_kv_cache(cfg, batch_size, max_len,
                                             dtype)}
        elif kind == "mamba":
            c = {"mamba": mamba.init_mamba_cache(cfg, batch_size)}
        elif kind == "mlstm":
            c = {"mlstm": xlstm.init_mlstm_cache(cfg, batch_size)}
        elif kind == "slstm":
            c = {"slstm": xlstm.init_slstm_cache(cfg, batch_size)}
        if cfg.is_encoder_decoder:
            c["cross_kv"] = None
        caches.append(c)
    return caches


def cache_bytes(cfg, batch_size, max_len, dtype=jnp.bfloat16) -> int:
    """HBM bytes of an ``init_caches`` tree, without allocating it.

    Abstract-evals the cache template, so the number tracks whatever layout
    ``cfg.quant.kv_bits`` selects (bf16 / int8 / bit-dense packed words +
    scales) — the per-slot term of the serving engine's HBM admission
    capacity (DESIGN.md §13)."""
    shapes = jax.eval_shape(
        lambda: init_caches(cfg, batch_size, max_len, dtype=dtype))
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(shapes))


def cache_page_bytes(cfg, page_size, dtype=jnp.bfloat16) -> int:
    """HBM bytes ONE pool page occupies summed across attention layers.

    The paged-serving capacity unit (DESIGN.md §18): the engine's HBM
    budget buys ``budget // cache_page_bytes`` pages.  Abstract-evals a
    one-page pool so the number tracks whatever layout
    ``cfg.quant.kv_bits`` selects (words + scale planes included).
    Recurrent layers contribute nothing — their per-slot states are not
    paged.  Returns 0 for attention-free stacks (the engine rejects
    paging those)."""
    def build():
        return [attention.init_paged_kv_cache(cfg, 1, page_size, dtype)
                for i in range(cfg.num_layers)
                if cfg.layer_kind(i) == "attn"]

    shapes = jax.eval_shape(build)
    return sum(leaf.size * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(shapes))


def loss_fn(logits, labels, aux=0.0, aux_weight=0.01):
    """Masked CE (labels < 0 are padding) + MoE load-balance aux."""
    logits = logits.astype(jnp.float32)
    mask = (labels >= 0)
    labels_safe = jnp.maximum(labels, 0)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, labels_safe[..., None],
                               axis=-1)[..., 0]
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    return ce + aux_weight * aux, ce

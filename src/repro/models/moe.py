"""Mixture-of-Experts: top-k router + two execution paths.

* 'einsum'  — capacity-bounded one-hot dispatch/combine with group blocking
  (MaxText-style).  Fully SPMD-partitionable: the expert dim of the dispatched
  tensors shards over the tensor axis when num_experts divides it (true EP —
  jamba 16e on model=16); otherwise experts keep FSDP+TP sharding
  (mixtral 8e — TP-within-expert, DESIGN.md §6).  Used by the dry-run.
* 'ragged'  — sort-by-expert + jax.lax.ragged_dot, dropless; the single-host
  serving fast path (beyond-paper optimization, benchmarked in §Perf).

Expert FFNs are SwiGLU with quantizable projections (the paper's technique
applies to each expert matrix).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import quant as quant_lib
from repro.models import common
from repro.parallel.sharding import constrain


def moe_init(key, cfg, *, dtype=jnp.float32):
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 4)

    def ek(key, din, dout, scale):
        w = jax.random.normal(key, (e, din, dout), jnp.float32) * scale
        return w.astype(dtype)

    p = {
        "router": common.dense_init(ks[0], d, e, dtype=jnp.float32),
        "up": {"kernel": ek(ks[1], d, f, 1 / np.sqrt(d))},
        "gate": {"kernel": ek(ks[2], d, f, 1 / np.sqrt(d))},
        "down": {"kernel": ek(ks[3], f, d, 1 / np.sqrt(f))},
    }
    if cfg.quant.enabled:
        for name in ("up", "gate", "down"):
            k = p[name]["kernel"]
            p[name]["w_step"] = quant_lib.init_step_from_data(
                k.astype(jnp.float32), cfg.quant.w_bits, True)
            p[name]["a_step"] = jnp.asarray(
                1.0 / np.sqrt(cfg.quant.qmax_a), jnp.float32)
    return p


def _expert_kernel(p, name, cfg, quant_mode):
    # experts use fake-quant in both QAT and packed-serve modes (packed
    # expert einsums are future work; DESIGN.md §5)
    k = p[name]["kernel"]
    if quant_mode in ("qat", "packed") and cfg.quant.enabled and "w_step" in p[name]:
        k = quant_lib.lsq_fake_quant(k.astype(jnp.float32),
                                     p[name]["w_step"], cfg.quant.w_bits,
                                     True)
    return k.astype(common.dtype_of(cfg.compute_dtype))


def _maybe_fq_act(x, p, name, cfg, quant_mode):
    if quant_mode in ("qat", "packed") and cfg.quant.enabled and "a_step" in p[name]:
        x = quant_lib.lsq_fake_quant(x.astype(jnp.float32),
                                     p[name]["a_step"], cfg.quant.a_bits,
                                     True)
    return x.astype(common.dtype_of(cfg.compute_dtype))


def router_probs(p, cfg, x):
    """Top-k routing probabilities.  x: [T, d] -> (probs [T,k], idx [T,k],
    aux_loss)."""
    logits = jnp.dot(x.astype(jnp.float32), p["router"]["kernel"])
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, cfg.num_experts_per_tok)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)
    # Shazeer load-balancing auxiliary loss.
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jax.nn.one_hot(top_i[:, 0], cfg.num_experts, dtype=jnp.float32),
        axis=0)
    aux = cfg.num_experts * jnp.sum(me * ce)
    return top_p, top_i, aux


def moe_apply_einsum(p, cfg, x, *, quant_mode="none"):
    """Capacity-dispatch path.  x: [B, S, d] -> [B, S, d], aux loss."""
    b, s, d = x.shape
    cd = common.dtype_of(cfg.compute_dtype)
    t = b * s
    xt = x.reshape(t, d)
    top_p, top_i, aux = router_probs(p, cfg, xt)

    g = max(1, min(cfg.moe_group_size, t))
    while t % g:
        g -= 1
    ng = t // g
    cap = int(np.ceil(g * cfg.num_experts_per_tok * cfg.capacity_factor
                      / cfg.num_experts))
    cap = max(cap, cfg.num_experts_per_tok)

    # position of each (token, choice) within its expert queue, per group
    one_hot = jax.nn.one_hot(top_i, cfg.num_experts, dtype=jnp.int32)
    oh = one_hot.reshape(ng, g, cfg.num_experts_per_tok, cfg.num_experts)
    flat = oh.reshape(ng, g * cfg.num_experts_per_tok, cfg.num_experts)
    pos = jnp.cumsum(flat, axis=1) - 1                     # queue slots
    pos = pos.reshape(ng, g, cfg.num_experts_per_tok, cfg.num_experts)
    keep = (pos < cap) & (oh > 0)
    disp = (jax.nn.one_hot(jnp.where(keep, pos, -1), cap, dtype=cd)
            * oh[..., None].astype(cd))                    # [ng,g,k,E,cap]
    dispatch = jnp.sum(disp, axis=2)                       # [ng,g,E,cap]
    probs = top_p.reshape(ng, g, cfg.num_experts_per_tok).astype(cd)
    combine = jnp.sum(disp * probs[..., None, None], axis=2)

    xg = xt.reshape(ng, g, d).astype(cd)
    expert_in = jnp.einsum("ngec,ngd->necd", dispatch, xg)  # [ng,E,cap,d]
    expert_in = constrain(expert_in, "dp", "model", None, None)
    ein = _maybe_fq_act(expert_in, p, "up", cfg, quant_mode)
    up = jnp.einsum("necd,edf->necf", ein,
                    _expert_kernel(p, "up", cfg, quant_mode))
    gate = jnp.einsum("necd,edf->necf", ein,
                      _expert_kernel(p, "gate", cfg, quant_mode))
    h = jax.nn.silu(gate) * up
    h = _maybe_fq_act(h, p, "down", cfg, quant_mode)
    out = jnp.einsum("necf,efd->necd", h,
                     _expert_kernel(p, "down", cfg, quant_mode))
    out = constrain(out, "dp", "model", None, None)
    y = jnp.einsum("ngec,necd->ngd", combine, out)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_apply_ragged(p, cfg, x, *, quant_mode="none"):
    """Dropless sort-based path using jax.lax.ragged_dot (single host)."""
    b, s, d = x.shape
    cd = common.dtype_of(cfg.compute_dtype)
    t, k = b * s, cfg.num_experts_per_tok
    xt = x.reshape(t, d)
    top_p, top_i, aux = router_probs(p, cfg, xt)

    flat_e = top_i.reshape(-1)                       # [t*k]
    order = jnp.argsort(flat_e, stable=True)
    tok_of = order // k
    sorted_x = jnp.take(xt, tok_of, axis=0).astype(cd)
    group_sizes = jnp.bincount(flat_e, length=cfg.num_experts)

    def rdot(lhs, name):
        return jax.lax.ragged_dot(
            lhs, _expert_kernel(p, name, cfg, quant_mode), group_sizes)

    sx = _maybe_fq_act(sorted_x, p, "up", cfg, quant_mode)
    h = jax.nn.silu(rdot(sx, "gate")) * rdot(sx, "up")
    h = _maybe_fq_act(h, p, "down", cfg, quant_mode)
    out = rdot(h, "down")                            # [t*k, d]
    w = jnp.take(top_p.reshape(-1), order)[:, None].astype(cd)
    y = jnp.zeros((t, d), cd).at[tok_of].add(out * w)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_apply(p, cfg, x, *, quant_mode="none", path="einsum"):
    if path == "ragged":
        return moe_apply_ragged(p, cfg, x, quant_mode=quant_mode)
    return moe_apply_einsum(p, cfg, x, quant_mode=quant_mode)

"""Gated (SwiGLU) and plain MLPs with quantizable projections."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import common
from repro.models.common import dense_apply, dense_init


def mlp_init(key, cfg, d_ff=None, *, gated=True, dtype=jnp.float32):
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"up": dense_init(ks[0], cfg.d_model, d_ff, dtype=dtype,
                          quantized=True, qcfg=cfg.quant),
         "down": dense_init(ks[1], d_ff, cfg.d_model, dtype=dtype,
                            quantized=True, qcfg=cfg.quant)}
    if gated:
        p["gate"] = dense_init(ks[2], cfg.d_model, d_ff, dtype=dtype,
                               quantized=True, qcfg=cfg.quant)
    return p


def mlp_apply(p, cfg, x, *, quant_mode="none"):
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    up = dense_apply(p["up"], x, **qm)
    if "gate" in p:
        h = jax.nn.silu(dense_apply(p["gate"], x, **qm)) * up
    else:
        h = jax.nn.gelu(up)
    return dense_apply(p["down"], h, **qm)

"""Mamba (selective SSM) block for the jamba hybrid architecture.

Standard Mamba-1: in-proj -> (x, z); depthwise causal conv1d + SiLU; input-
dependent (dt, B, C); selective scan; gate by SiLU(z); out-proj.  The scan
carries state [B, d_inner, d_state] so decode is O(1) per token — this is why
jamba runs the long_500k shape (DESIGN.md §5).

The in/out projections are quantizable (the paper's technique); the recurrence
stays fp32 for stability.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import common
from repro.models.common import dense_apply, dense_init


def mamba_init(key, cfg, *, dtype=jnp.float32):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    ds = cfg.ssm_state_dim
    dtr = cfg.dt_rank
    ks = jax.random.split(key, 6)
    p = {
        "in_proj": dense_init(ks[0], d, 2 * di, dtype=dtype, quantized=True,
                              qcfg=cfg.quant),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, di),
                                     jnp.float32)
                   / np.sqrt(cfg.ssm_conv_width)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dtr + 2 * ds, dtype=dtype,
                             quantized=True, qcfg=cfg.quant),
        "dt_proj": dense_init(ks[3], dtr, di, use_bias=True, dtype=dtype),
        # S4D-real initialization of A (negative real spectrum).
        "A_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, ds + 1, dtype=jnp.float32), (di, ds))),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d, dtype=dtype, quantized=True,
                               qcfg=cfg.quant),
    }
    return p


def init_mamba_cache(cfg, batch, dtype=jnp.float32):
    di = cfg.ssm_expand * cfg.d_model
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, cfg.ssm_state_dim), dtype),
    }


def _ssm_params(p, cfg, xc, quant_mode):
    """Input-dependent dt, B, C from the conved activation xc [B, S, di]."""
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    dtr, ds = cfg.dt_rank, cfg.ssm_state_dim
    dbc = dense_apply(p["x_proj"], xc, **qm).astype(jnp.float32)
    dt_r, b_mat, c_mat = jnp.split(dbc, [dtr, dtr + ds], axis=-1)
    dt = jax.nn.softplus(
        dense_apply(p["dt_proj"], dt_r.astype(cd),
                    compute_dtype=jnp.float32))
    return dt, b_mat, c_mat


def mamba_apply(p, cfg, x, *, quant_mode="none", cache=None,
                cache_index=None, cache_valid=None):
    """x: [B, S, d].  Returns (y, new_cache).

    With cache + cache_index the recurrence continues from the cached
    (conv, ssm) state for any window length S (single-token decode or a
    chunked-prefill window).  ``cache_valid`` [B] gates ragged windows:
    only each row's valid-prefix tokens advance its state (DESIGN.md §12).
    """
    b, s, _ = x.shape
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    di = cfg.ssm_expand * cfg.d_model
    cw = cfg.ssm_conv_width
    decoding = cache is not None and cache_index is not None
    vlen = None
    if decoding:
        vlen = (jnp.full((b,), s, jnp.int32) if cache_valid is None
                else jnp.asarray(cache_valid, jnp.int32))

    xz = dense_apply(p["in_proj"], x, **qm)
    xi, z = jnp.split(xz, 2, axis=-1)                 # [B, S, di] each
    xi32 = xi.astype(jnp.float32)

    # depthwise causal conv1d
    if decoding:
        # window continuation: conv history comes from the cache
        hist = jnp.concatenate([cache["conv"].astype(jnp.float32), xi32],
                               axis=1)                # [B, cw-1+S, di]
        windows = jnp.stack([hist[:, i:i + s] for i in range(cw)],
                            axis=2)                   # [B, S, cw, di]
        conv_out = jnp.einsum("bskd,kd->bsd", windows,
                              p["conv_w"].astype(jnp.float32))
        conv_out = conv_out + p["conv_b"].astype(jnp.float32)
        # history after each row consumed its vlen[b] valid tokens (ragged
        # windows are valid-prefix): per-row shifted window of hist, taken
        # with a one-hot contraction (plain einsum, no per-row gather)
        t_hist = hist.shape[1]
        want = vlen[:, None, None] + jnp.arange(cw - 1)[None, :, None]
        onehot = (want == jnp.arange(t_hist)[None, None, :]) \
            .astype(jnp.float32)                   # [B, cw-1, T]
        new_conv = jnp.einsum("bwt,btd->bwd", onehot,
                              hist).astype(cache["conv"].dtype)
    else:
        padded = jnp.pad(xi32, ((0, 0), (cw - 1, 0), (0, 0)))
        windows = jnp.stack(
            [padded[:, i:i + s] for i in range(cw)], axis=2)  # [B,S,cw,di]
        conv_out = jnp.einsum("bskd,kd->bsd", windows,
                              p["conv_w"].astype(jnp.float32))
        conv_out = conv_out + p["conv_b"].astype(jnp.float32)
        new_conv = padded[:, -(cw - 1):] if cache is not None else None
    xc = jax.nn.silu(conv_out)                        # [B, S|1, di]

    dt, b_mat, c_mat = _ssm_params(p, cfg, xc.astype(cd), quant_mode)
    a = -jnp.exp(p["A_log"])                          # [di, ds]

    da = jnp.exp(dt[..., None] * a)                   # [B,S,di,ds]
    dbx = (dt * xc)[..., None] * b_mat[:, :, None, :]  # [B,S,di,ds]

    if decoding:
        def dstep(h, inp):
            da_t, dbx_t, c_t, keep = inp
            h2 = h * da_t + dbx_t
            y_t = jnp.einsum("bds,bs->bd", h2, c_t)
            # invalid (pad) tokens emit garbage y but leave the state alone
            return jnp.where(keep[:, None, None], h2, h), y_t

        keep = (jnp.arange(s)[None, :] < vlen[:, None]).T  # [S, B]
        last, ys = jax.lax.scan(
            dstep, cache["ssm"].astype(jnp.float32),
            (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0),
             jnp.moveaxis(c_mat, 1, 0), keep))
        y = jnp.moveaxis(ys, 0, 1)                    # [B, S, di]
        new_ssm = last.astype(cache["ssm"].dtype)
    else:
        def step(h, inp):
            da_t, dbx_t, c_t = inp
            h = h * da_t + dbx_t
            return h, jnp.einsum("bds,bs->bd", h, c_t)

        h0 = jnp.zeros((b, di, cfg.ssm_state_dim), jnp.float32)
        last, ys = jax.lax.scan(
            step, h0, (jnp.moveaxis(da, 1, 0), jnp.moveaxis(dbx, 1, 0),
                       jnp.moveaxis(c_mat, 1, 0)))
        y = jnp.moveaxis(ys, 0, 1)                    # [B, S, di]
        new_ssm = last if cache is not None else None

    y = y + xc.astype(jnp.float32) * p["D"]
    y = y * jax.nn.silu(z.astype(jnp.float32))
    out = dense_apply(p["out_proj"], y.astype(cd), **qm)
    new_cache = None
    if cache is not None:
        new_cache = {"conv": new_conv, "ssm": new_ssm}
    return out, new_cache

"""Attention: GQA / MQA, sliding-window (ring-buffer KV), M-RoPE, cross-attn,
query-chunked exact softmax (flash-style memory behaviour in pure JAX).

Projections are quantizable Dense layers (the paper's technique applies to
them); the score/value einsums stay bf16 (DESIGN.md §5).  The decode KV
cache is additionally storable at int8 or sub-byte (bit-dense packed words,
cfg.quant.kv_bits; DESIGN.md §13) with unpack+dequant fused into the
q-chunked loop.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.models import common
from repro.models.common import dense_apply, dense_init

NEG_INF = -1e30


def attention_init(key, cfg, *, cross=False, dtype=jnp.float32):
    hd = cfg.resolved_head_dim
    ks = jax.random.split(key, 4)
    q = dense_init(ks[0], cfg.d_model, cfg.num_heads * hd,
                   use_bias=cfg.qkv_bias, dtype=dtype,
                   quantized=True, qcfg=cfg.quant)
    k = dense_init(ks[1], cfg.d_model, cfg.num_kv_heads * hd,
                   use_bias=cfg.qkv_bias, dtype=dtype,
                   quantized=True, qcfg=cfg.quant)
    v = dense_init(ks[2], cfg.d_model, cfg.num_kv_heads * hd,
                   use_bias=cfg.qkv_bias, dtype=dtype,
                   quantized=True, qcfg=cfg.quant)
    o = dense_init(ks[3], cfg.num_heads * hd, cfg.d_model, dtype=dtype,
                   quantized=True, qcfg=cfg.quant,
                   scale=1.0 / (cfg.num_heads * hd) ** 0.5)
    p = {"q": q, "k": k, "v": v, "o": o}
    del cross
    return p


def init_kv_cache(cfg, batch, max_len, dtype=jnp.bfloat16):
    """Ring-buffer KV cache; SWA archs allocate only the window.

    ``cfg.quant.kv_bits`` selects the storage precision (DESIGN.md §13):
      0 / 16 — full ``dtype`` (bf16 in serving), the baseline.
      8      — int8 values + per-(pos, kv-head) bf16 absmax scales (~2x).
      4 / 2  — bit-dense int32 words (``packing.pack_words`` along head_dim,
               ``32 // kv_bits`` values per word, zero-padded tail) + the
               same per-(pos, kv-head) bf16 scales (~4x / ~8x).  The read
               path never materializes the full-precision cache: unpack +
               dequant are fused into the q-chunked attention loop.
    """
    hd = cfg.resolved_head_dim
    size = min(max_len, cfg.sliding_window) if cfg.sliding_window else max_len
    kvh = cfg.num_kv_heads
    bits = getattr(cfg.quant, "kv_bits", 0)
    if bits == 8:
        return {
            "k": jnp.zeros((batch, size, kvh, hd), jnp.int8),
            "v": jnp.zeros((batch, size, kvh, hd), jnp.int8),
            "k_scale": jnp.zeros((batch, size, kvh), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, size, kvh), jnp.bfloat16),
        }
    if bits in (4, 2):
        hd_words = -(-hd // (32 // bits))
        return {
            "k": jnp.zeros((batch, size, kvh, hd_words), jnp.int32),
            "v": jnp.zeros((batch, size, kvh, hd_words), jnp.int32),
            "k_scale": jnp.zeros((batch, size, kvh), jnp.bfloat16),
            "v_scale": jnp.zeros((batch, size, kvh), jnp.bfloat16),
        }
    if bits not in (0, 16):
        raise ValueError(f"unsupported kv_bits {bits}; expected 0/16/8/4/2")
    return {
        "k": jnp.zeros((batch, size, kvh, hd), dtype),
        "v": jnp.zeros((batch, size, kvh, hd), dtype),
    }


def init_paged_kv_cache(cfg, num_pages, page_size, dtype=jnp.bfloat16):
    """Paged KV pool: ``num_pages`` pages of ``page_size`` token rows.

    Same per-row layouts as :func:`init_kv_cache` with the slot-contiguous
    ``[B, S, ...]`` leading dims replaced by ``[P, page_size, ...]`` — the
    kv-head axis stays axis 2, so the serving kv-head shardings
    (DESIGN.md §15) apply to pools unchanged while the page axis
    replicates.  One page-id space serves every attention layer: layer
    ``i``'s pool is indexed by the same block tables (serve/pages.py).
    Sub-byte layouts additionally require ``page_size`` to be a multiple
    of the word-packing tail (serve/pages.validate_page_size) so each
    page holds whole int32 words and dequantizes independently; the
    per-(pos, kv-head) scale planes page alongside the words.

    Sliding-window archs keep the unpaged ring (ring slot reuse and page
    indirection do not compose; the engine rejects the combination).
    """
    if cfg.sliding_window:
        raise ValueError(
            "paged KV cache does not support sliding-window ring caches; "
            "serve sliding-window archs unpaged")
    hd = cfg.resolved_head_dim
    kvh = cfg.num_kv_heads
    bits = getattr(cfg.quant, "kv_bits", 0)
    if bits == 8:
        return {
            "k": jnp.zeros((num_pages, page_size, kvh, hd), jnp.int8),
            "v": jnp.zeros((num_pages, page_size, kvh, hd), jnp.int8),
            "k_scale": jnp.zeros((num_pages, page_size, kvh), jnp.bfloat16),
            "v_scale": jnp.zeros((num_pages, page_size, kvh), jnp.bfloat16),
        }
    if bits in (4, 2):
        hd_words = -(-hd // (32 // bits))
        return {
            "k": jnp.zeros((num_pages, page_size, kvh, hd_words), jnp.int32),
            "v": jnp.zeros((num_pages, page_size, kvh, hd_words), jnp.int32),
            "k_scale": jnp.zeros((num_pages, page_size, kvh), jnp.bfloat16),
            "v_scale": jnp.zeros((num_pages, page_size, kvh), jnp.bfloat16),
        }
    if bits not in (0, 16):
        raise ValueError(f"unsupported kv_bits {bits}; expected 0/16/8/4/2")
    return {
        "k": jnp.zeros((num_pages, page_size, kvh, hd), dtype),
        "v": jnp.zeros((num_pages, page_size, kvh, hd), dtype),
    }


def _kv_quantize(x, bits=8):
    """[B,S,KVH,hd] float -> (stored lattice, bf16 per-(pos,head) scales).

    bits == 8: signed int8 absmax (the legacy layout).  bits in (4, 2):
    midpoint-zero-point unsigned lattice — scale targets ``qmax - zp`` steps
    (the calibrate_absmax convention) so +amax hits exactly ``qmax`` — packed
    bit-dense along head_dim into int32 words.  The 1e-8 scale floor keeps
    all-zero rows (untouched cache slots, zero projections) NaN-free.
    """
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    if bits == 8:
        scale = jnp.maximum(amax / 127.0, 1e-8)
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
        return q, scale.astype(jnp.bfloat16)
    zp = 1 << (bits - 1)
    qmax = (1 << bits) - 1
    scale = jnp.maximum(amax / (qmax - zp), 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]) + zp,
                 0, qmax).astype(jnp.int32)
    return packing.pack_words(q, bits, axis=-1), scale.astype(jnp.bfloat16)


def _kv_dequantize(q, scale, dtype=jnp.float32, bits=8, hd=None):
    # compute in the target dtype: the lattice values are exact in bf16, and
    # f32 intermediates here would double the dominant decode traffic (§Perf)
    if bits == 8:
        return q.astype(dtype) * scale.astype(dtype)[..., None]
    zp = 1 << (bits - 1)
    vals = packing.unpack_words(q, bits, hd, axis=-1)
    return (vals.astype(dtype) - zp) * scale.astype(dtype)[..., None]


def _chunked_attention(q, kv_fn, mask_fn, q_positions, chunk: int):
    """Exact softmax attention, q-chunked to bound the score buffer.

    q: [B, Sq, H, hd]; kv_fn() -> (k, v) each [B, Sk, KVH, hd] — invoked
    INSIDE the chunk body so a quantized/bit-packed KV cache is expanded
    (unpack + dequant) per chunk in registers/VMEM and fused into the score
    and value einsums, never materialized at full precision across the whole
    call; mask_fn(qpos[chunk]) -> [B, chunk, Sk] boolean validity.
    Returns [B, Sq, H, hd].
    """
    b, sq, h, hd = q.shape
    scale = hd ** -0.5
    # operands stay in their storage dtype (bf16 on TPU) with f32 MXU
    # accumulation — avoids materializing f32 copies of the whole KV cache
    # (§Perf cell-C iteration 2: the f32 upcast was 2x the cache traffic)
    opd = q.dtype

    def one_chunk(qc, qpos):
        # qc: [B, C, H, hd]
        k, v = kv_fn()
        kvh = k.shape[2]
        groups = h // kvh
        qg = (qc.astype(jnp.float32) * scale).astype(opd)
        qg = qg.reshape(b, qc.shape[1], kvh, groups, hd)
        scores = jnp.einsum("bckgd,bskd->bckgs", qg, k.astype(opd),
                            preferred_element_type=jnp.float32)
        valid = mask_fn(qpos)[:, :, None, None, :]        # [B,C,1,1,Sk]
        scores = jnp.where(valid, scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bckgs,bskd->bckgd", probs.astype(opd),
                         v.astype(opd),
                         preferred_element_type=jnp.float32)
        return out.reshape(b, qc.shape[1], h, hd)

    if sq <= chunk:
        return one_chunk(q, q_positions).astype(q.dtype)
    # per-chunk remat: backward recomputes the [C, Sk] score block instead of
    # storing scores+probs for every chunk (flash-style memory behaviour)
    chunk_fn = jax.checkpoint(lambda args: one_chunk(*args))
    n = sq // chunk
    rem = sq - n * chunk
    qs = jnp.moveaxis(
        q[:, :n * chunk].reshape(b, n, chunk, h, hd), 1, 0)
    ps = jnp.moveaxis(
        q_positions[:, :n * chunk].reshape(b, n, chunk), 1, 0)
    outs = jax.lax.map(chunk_fn, (qs, ps))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, n * chunk, h, hd)
    if rem:
        tail = one_chunk(q[:, n * chunk:], q_positions[:, n * chunk:])
        out = jnp.concatenate([out, tail], axis=1)
    return out.astype(q.dtype)


def precompute_cross_kv(p, cfg, enc_out, *, quant_mode="none"):
    """Project encoder states to K/V once (reused every decode step)."""
    b = enc_out.shape[0]
    hd = cfg.resolved_head_dim
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)
    k = dense_apply(p["k"], enc_out, **qm).reshape(b, -1, cfg.num_kv_heads,
                                                   hd)
    v = dense_apply(p["v"], enc_out, **qm).reshape(b, -1, cfg.num_kv_heads,
                                                   hd)
    return k, v


def _constrain_kv_heads(tree, axis):
    """Pin cache-layout tensors to the serving kv-head shard axis.

    ``axis`` is the mesh axis the serving ShardPlan sharded the kv-head
    dim over (DESIGN.md §15); the constraint keeps the quantize -> pack ->
    scatter write chain head-local so GSPMD neither gathers the incoming
    [B, s, KVH, hd] slice nor reshards the ring between steps.  Applies to
    K/V (and packed-word) tensors [B, S, KVH, hd|words] and the
    per-(pos, kv-head) scale planes [B, S, KVH]; no-op when ``axis`` is
    None or outside a mesh context (sharding.constrain guards)."""
    if axis is None:
        return tree
    from repro.parallel.sharding import constrain

    def one(t):
        if t.ndim == 4:
            return constrain(t, None, None, axis, None)
        if t.ndim == 3:
            return constrain(t, None, None, axis)
        return t

    if isinstance(tree, dict):
        return {k: one(v) for k, v in tree.items()}
    return one(tree)


def _fused_decode_epilogue(p, cfg, q, read_cache, valid_len, positions,
                           kv_bits, new_cache, qm, kv_shard_axis,
                           block_tables=None):
    """Decode tail via the fused flash-decoding read (DESIGN.md §20):
    kernels/ulppack_attention walks the stored — possibly paged — cache in
    online-softmax groups, so neither the dequantized view, the gathered
    paged view, nor a full score block materializes.  ``valid_len`` [B] is
    each row's live logical-view prefix; the group mask
    ``pos < valid_len & pos <= qpos`` is exactly the legacy
    ``_ring_positions*`` visibility for non-windowed caches.  Sharded
    serving (``kv_shard_axis``) pins the 'xla' backend — the only GSPMD-
    partitionable one."""
    from repro.kernels import ulppack_attention

    b, sq, h, hd = q.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, sq))
    backend = "xla" if kv_shard_axis is not None else "auto"
    out = ulppack_attention.fused_decode_attention(
        q, read_cache, valid_len, positions, kv_bits=kv_bits, hd=hd,
        block_tables=block_tables, backend=backend)
    out = dense_apply(p["o"], out.reshape(b, sq, h * hd), **qm)
    return out, new_cache


def _use_fused_decode(window, kv_x, idx, sq) -> bool:
    """Trace-time gate for the fused decode read: self-attention decode
    over a non-windowed cache (sliding-window rings keep the legacy ring-
    position mask; scalar lockstep callers beyond one token predate the
    per-row valid_len semantics)."""
    from repro.kernels import ulppack_attention

    if not ulppack_attention.enabled() or window or kv_x is not None:
        return False
    return idx.ndim > 0 or sq == 1


def _attention_epilogue(p, cfg, q, kv_fn, mask_fn, positions, q_chunk,
                        skv, kv_bits, new_cache, qm):
    """Shared attention tail: positions broadcast, autotuned q-chunk
    lookup, the q-chunked softmax, and the output projection."""
    b, sq, h, hd = q.shape
    if positions.ndim == 1:
        positions = jnp.broadcast_to(positions[None, :], (b, sq))
    if q_chunk is None:
        from repro.kernels import autotune  # trace-time lookup, static ints
        q_chunk = autotune.attention_chunk_for(
            b, sq, int(skv), cfg.num_heads, cfg.num_kv_heads, hd,
            int(kv_bits))
    out = _chunked_attention(q, kv_fn, mask_fn, positions, q_chunk)
    out = dense_apply(p["o"], out.reshape(b, sq, h * hd), **qm)
    return out, new_cache


def attention_apply(p, cfg, x, *, positions, quant_mode="none",
                    cache=None, cache_index=None, cache_valid=None,
                    kv_x=None, kv_positions=None, causal=True,
                    positions3=None, q_chunk=None, cross_kv=None,
                    kv_shard_axis=None, block_tables=None):
    """Full attention forward.

    ``q_chunk=None`` consults the autotune cache for the fused-attention
    chunk tuned for this (batch, q-len, kv-len, heads, head-dim, kv_bits)
    signature (kernels/autotune.py), falling back to 512; pass an int to
    pin it.

    Modes:
      * training/prefill: cache=None (or cache provided to be FILLED when
        cache_index is None -> returns (out, new_cache)).
      * decode: cache + cache_index given, x is [B, 1, d].  A scalar
        cache_index is the lockstep path (all rows share one position); a
        [B] vector gives each row its own write offset (ragged batches,
        DESIGN.md §12), with x [B, S, d] for chunked prefill.
      * ragged windows: cache_valid [B] counts the valid-prefix tokens of
        each row's window; trailing pad tokens are never written to the
        cache (0 = dead slot, fully masked).
      * cross-attention: kv_x (encoder states) given; non-causal, no RoPE
        ring-buffer concerns.
      * paged decode: ``block_tables`` [B, n_pages] maps each row's
        logical page j to a physical page of a pooled cache
        ([P, page_size, KVH, ...], init_paged_kv_cache).  Writes scatter
        through the table; reads gather the row's pages back into a
        logical [B, n_pages*page_size, ...] view INSIDE the q-chunk body,
        so fused sub-byte dequant is preserved bit-exactly (positions the
        mask admits hold values identical to the unpaged ring, and masked
        rows contribute exactly-zero probability).  Vector cache_index
        only; sliding-window archs stay unpaged (DESIGN.md §18).
    """
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    cd = common.dtype_of(cfg.compute_dtype)
    qm = dict(qcfg=cfg.quant, quant_mode=quant_mode, compute_dtype=cd)

    q = dense_apply(p["q"], x, **qm).reshape(b, sq, cfg.num_heads, hd)
    if cross_kv is not None:
        k, v = cross_kv
        kv_x = True  # marks cross-attention masking below
    else:
        kv_in = kv_x if kv_x is not None else x
        k = dense_apply(p["k"], kv_in, **qm).reshape(b, -1,
                                                     cfg.num_kv_heads, hd)
        v = dense_apply(p["v"], kv_in, **qm).reshape(b, -1,
                                                     cfg.num_kv_heads, hd)

    if kv_x is None:  # self-attention: rotate q and k
        if cfg.mrope and positions3 is not None:
            q = common.apply_mrope(q, positions3, cfg.mrope_sections,
                                   cfg.rope_theta)
            k = common.apply_mrope(k, positions3, cfg.mrope_sections,
                                   cfg.rope_theta)
        else:
            q = common.apply_rope(q, positions, cfg.rope_theta)
            k = common.apply_rope(k, positions, cfg.rope_theta)

    window = cfg.sliding_window
    kv_bits = getattr(cfg.quant, "kv_bits", 0)
    new_cache = None

    if cache is not None and cache_index is not None:
        # ---- decode / chunked prefill: write new k/v into the ring ----
        # under serving TP the incoming slice and the written ring stay
        # pinned to the kv-head shard axis (no-op when axis is None)
        k = _constrain_kv_heads(k, kv_shard_axis)
        v = _constrain_kv_heads(v, kv_shard_axis)
        idx = jnp.asarray(cache_index)
        if block_tables is not None:
            # ---- paged pool: scatter/gather through the block table ----
            if window:
                raise NotImplementedError(
                    "paged KV cache + sliding-window ring do not compose; "
                    "serve sliding-window archs unpaged")
            if idx.ndim == 0:
                raise NotImplementedError(
                    "paged decode is vector-indexed (per-slot positions); "
                    "pass cache_index as a [B] array")
            bt = jnp.asarray(block_tables, jnp.int32)
            page_rows = cache["k"].shape[1]
            size = bt.shape[1] * page_rows     # logical view length
            vlen = (jnp.full((b,), sq, jnp.int32) if cache_valid is None
                    else jnp.asarray(cache_valid, jnp.int32))
            offs = jnp.arange(sq, dtype=jnp.int32)
            wpos = idx[:, None] + offs[None, :]                # [B, sq]
            page_idx = jnp.clip(wpos // page_rows, 0, bt.shape[1] - 1)
            phys = jnp.take_along_axis(bt, page_idx, axis=1)
            new_cache = _cache_write_paged(
                cache, k, v, phys, wpos % page_rows,
                offs[None, :] < vlen[:, None], kv_bits)
            # logical row j of the gathered view holds absolute position
            # j by construction (page j // page_rows, row j % page_rows),
            # so the unpaged no-window position map applies verbatim
            kv_pos = _ring_positions_batch(idx + vlen - 1, size,
                                           0)                  # [B, size]
            new_cache = _constrain_kv_heads(new_cache, kv_shard_axis)
            if _use_fused_decode(window, kv_x, idx, sq):
                # zero-copy step: the fused read walks the pool through
                # the block table, so the [B, size] gather never happens
                return _fused_decode_epilogue(
                    p, cfg, q, new_cache, idx + vlen, positions, kv_bits,
                    new_cache, qm, kv_shard_axis, block_tables=bt)
            read_cache, kv_dtype = new_cache, k.dtype
            kv_fn = lambda: _paged_cache_read(read_cache, bt, kv_dtype,
                                              kv_bits, hd)

            def mask_fn(qpos):
                kp = kv_pos[:, None, :]
                m = kp <= qpos[:, :, None]
                m &= kp >= 0
                return m

            kv_view_len = size
            return _attention_epilogue(p, cfg, q, kv_fn, mask_fn,
                                       positions, q_chunk, kv_view_len,
                                       kv_bits, new_cache, qm)
        size = cache["k"].shape[1]
        if idx.ndim == 0:
            # lockstep scalar path: every row writes the same slot
            slot = idx % size if window else idx
            new_cache = _cache_write(cache, k, v, slot, kv_bits)
            kv_pos = _ring_positions(idx, size, window)        # [size]
        else:
            # per-slot positions: row b writes its window at absolute
            # positions idx[b]..idx[b]+sq-1; tokens past cache_valid[b]
            # are dropped so ragged rows never corrupt the ring
            if window and sq > 1:
                raise NotImplementedError(
                    "chunked ragged prefill over a sliding-window ring "
                    "would overwrite slots still visible to earlier "
                    "queries of the same window; feed ring-cache archs "
                    "token-by-token (ServingEngine clamps prefill_chunk "
                    "to 1 for them)")
            vlen = (jnp.full((b,), sq, jnp.int32) if cache_valid is None
                    else jnp.asarray(cache_valid, jnp.int32))
            offs = jnp.arange(sq, dtype=jnp.int32)
            wpos = idx[:, None] + offs[None, :]                # [B, sq]
            slots = wpos % size if window else wpos
            new_cache = _cache_write_ragged(
                cache, k, v, slots, offs[None, :] < vlen[:, None], kv_bits)
            kv_pos = _ring_positions_batch(idx + vlen - 1, size,
                                           window)            # [B, size]
        new_cache = _constrain_kv_heads(new_cache, kv_shard_axis)
        if _use_fused_decode(window, kv_x, idx, sq):
            valid_len = (jnp.full((b,), idx + sq, jnp.int32)
                         if idx.ndim == 0 else idx + vlen)
            return _fused_decode_epilogue(p, cfg, q, new_cache, valid_len,
                                          positions, kv_bits, new_cache,
                                          qm, kv_shard_axis)
        # deferred read: _chunked_attention calls this inside the chunk
        # body, so a packed cache is unpacked+dequantized fused with the
        # score/value einsums (the bf16 cache copy never exists whole)
        read_cache, kv_dtype = new_cache, k.dtype
        kv_fn = lambda: _cache_read(read_cache, kv_dtype, kv_bits, hd)

        def mask_fn(qpos):
            kp = kv_pos[:, None, :] if kv_pos.ndim == 2 \
                else kv_pos[None, None, :]
            m = kp <= qpos[:, :, None]
            m &= kp >= 0
            if window:
                m &= (qpos[:, :, None] - kp) < window
            return m
    else:
        # ---- training / prefill ----
        kv_fn = lambda: (k, v)  # attends over the raw (unquantized) k/v
        if cache is not None:  # prefill fills the cache
            size = cache["k"].shape[1]
            if window and sq > size:
                # ring layout: slot = pos % size for the last `size` tokens
                roll = (sq % size)
                new_cache = _cache_write(cache, k[:, -size:], v[:, -size:],
                                         0, kv_bits)
                new_cache = {kk: jnp.roll(vv, roll, axis=1)
                             for kk, vv in new_cache.items()}
            else:
                new_cache = _cache_write(cache, k, v, 0, kv_bits)
            new_cache = _constrain_kv_heads(new_cache, kv_shard_axis)
        if kv_x is not None:
            kv_pos = (kv_positions if kv_positions is not None
                      else jnp.arange(k.shape[1]))[None, :]

            def mask_fn(qpos):
                return jnp.broadcast_to(
                    kv_pos[:, None, :] >= 0,
                    (qpos.shape[0], qpos.shape[1], k.shape[1]))
        else:
            kv_pos = positions

            def mask_fn(qpos):
                kp = kv_pos[:, None, :] if kv_pos.ndim == 2 \
                    else kv_pos[None, None, :]
                m = jnp.ones((qpos.shape[0], qpos.shape[1], k.shape[1]),
                             bool)
                if causal:
                    m &= kp <= qpos[:, :, None]
                if window:
                    m &= (qpos[:, :, None] - kp) < window
                return m

    skv = (cache["k"].shape[1] if cache is not None
           and cache_index is not None else k.shape[1])
    return _attention_epilogue(p, cfg, q, kv_fn, mask_fn, positions,
                               q_chunk, skv, kv_bits, new_cache, qm)


def _cache_write(cache, k, v, slot, kv_bits=0):
    """Write a [B, s, KVH, hd] float slice at `slot` (quantizing — and for
    sub-byte ``kv_bits`` word-packing along head_dim — when the cache is
    quantized)."""
    dus = jax.lax.dynamic_update_slice_in_dim
    if "k_scale" in cache:
        qk, sk = _kv_quantize(k, kv_bits)
        qv, sv = _kv_quantize(v, kv_bits)
        return {"k": dus(cache["k"], qk, slot, 1),
                "v": dus(cache["v"], qv, slot, 1),
                "k_scale": dus(cache["k_scale"], sk, slot, 1),
                "v_scale": dus(cache["v_scale"], sv, slot, 1)}
    return {"k": dus(cache["k"], k.astype(cache["k"].dtype), slot, 1),
            "v": dus(cache["v"], v.astype(cache["v"].dtype), slot, 1)}


def _cache_write_ragged(cache, k, v, slots, valid, kv_bits=0):
    """Per-row ragged write: token j of row b lands at ring slot
    ``slots[b, j]``; tokens with ``valid[b, j]`` False are redirected out
    of bounds and dropped (scatter ``mode='drop'``), so pad tokens never
    overwrite live entries.  O(window tokens) per call — the decode hot
    path writes one slot per row, like the lockstep ``_cache_write``.

    Callers guarantee a row never writes the same slot twice in one call
    (the windowed sq > 1 case is rejected upstream), so scatter duplicate
    semantics are never exercised.
    """
    size = cache["k"].shape[1]
    bi = jnp.arange(k.shape[0], dtype=jnp.int32)[:, None]
    tgt = jnp.where(valid, slots, size)

    def put(buf, val):
        return buf.at[bi, tgt].set(val.astype(buf.dtype), mode="drop")

    if "k_scale" in cache:
        qk, sk = _kv_quantize(k, kv_bits)
        qv, sv = _kv_quantize(v, kv_bits)
        return {"k": put(cache["k"], qk), "v": put(cache["v"], qv),
                "k_scale": put(cache["k_scale"], sk),
                "v_scale": put(cache["v_scale"], sv)}
    return {"k": put(cache["k"], k), "v": put(cache["v"], v)}


def _cache_write_paged(cache, k, v, pages, rows, valid, kv_bits=0):
    """Block-table scatter: token j of row b lands at physical page
    ``pages[b, j]``, row ``rows[b, j]`` of the pool.  Invalid tokens are
    redirected past the pool (scatter ``mode='drop'``), exactly like the
    ragged ring write.  Quantization/word-packing happen per incoming
    token row, so the stored words and scale planes are value-identical
    to the unpaged layout at the same absolute positions."""
    num_pages = cache["k"].shape[0]
    tgt = jnp.where(valid, pages, num_pages)

    def put(buf, val):
        return buf.at[tgt, rows].set(val.astype(buf.dtype), mode="drop")

    if "k_scale" in cache:
        qk, sk = _kv_quantize(k, kv_bits)
        qv, sv = _kv_quantize(v, kv_bits)
        return {"k": put(cache["k"], qk), "v": put(cache["v"], qv),
                "k_scale": put(cache["k_scale"], sk),
                "v_scale": put(cache["v_scale"], sv)}
    return {"k": put(cache["k"], k), "v": put(cache["v"], v)}


def _paged_cache_read(cache, block_tables, dtype, kv_bits=0, hd=None):
    """Gather each row's pages into the logical [B, n_pages*ps, KVH, ...]
    view and dequantize.  Called inside the q-chunk body (kv_fn), so the
    gather + fused unpack/dequant stay per chunk — the full-precision
    cache never exists whole, same as the unpaged read path."""
    def gather(buf):
        g = buf[block_tables]                # [B, n_pages, ps, KVH, ...]
        return g.reshape(g.shape[0], -1, *g.shape[3:])

    if "k_scale" in cache:
        return (_kv_dequantize(gather(cache["k"]), gather(cache["k_scale"]),
                               dtype, kv_bits, hd),
                _kv_dequantize(gather(cache["v"]), gather(cache["v_scale"]),
                               dtype, kv_bits, hd))
    return gather(cache["k"]), gather(cache["v"])


def _ring_positions_batch(last, size, window):
    """Batched `_ring_positions`: absolute positions stored per ring slot
    for each row given its last written position ``last [B]`` (-1 = row
    empty).  Plain broadcast arithmetic (no vmap)."""
    slots = jnp.arange(size, dtype=jnp.int32)[None, :]
    last = last[:, None]
    if not window:
        return jnp.where(slots <= last, slots, -1)
    cur_slot = last % size
    pos = last - ((cur_slot - slots) % size)
    return jnp.where(pos >= 0, pos, -1)


def _cache_read(cache, dtype, kv_bits=0, hd=None):
    if "k_scale" in cache:
        return (_kv_dequantize(cache["k"], cache["k_scale"], dtype,
                               kv_bits, hd),
                _kv_dequantize(cache["v"], cache["v_scale"], dtype,
                               kv_bits, hd))
    return cache["k"], cache["v"]


def _ring_positions(cache_index, size, window):
    """Absolute positions stored in each ring slot (-1 = empty)."""
    slots = jnp.arange(size)
    if not window:
        pos = slots
        return jnp.where(slots <= cache_index, pos, -1)
    # slot s holds the latest position p <= cache_index with p % size == s
    cur_slot = cache_index % size
    pos = cache_index - ((cur_slot - slots) % size)
    return jnp.where(pos >= 0, pos, -1)

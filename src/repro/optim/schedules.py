"""LR schedules: cosine-with-warmup and WSD (warmup-stable-decay, MiniCPM)."""

from __future__ import annotations

import jax.numpy as jnp


def cosine_with_warmup(step, *, peak_lr, warmup_steps, total_steps,
                       final_frac=0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    progress = jnp.clip((step - warmup_steps)
                        / jnp.maximum(total_steps - warmup_steps, 1), 0, 1)
    cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(
        jnp.pi * progress))
    return jnp.where(step < warmup_steps, warm, peak_lr * cos)


def wsd(step, *, peak_lr, warmup_steps, total_steps, decay_frac=0.1,
        final_frac=0.01):
    """MiniCPM's Warmup-Stable-Decay: flat plateau, sharp final decay."""
    step = jnp.asarray(step, jnp.float32)
    decay_steps = decay_frac * total_steps
    decay_start = total_steps - decay_steps
    warm = peak_lr * step / jnp.maximum(warmup_steps, 1)
    progress = jnp.clip((step - decay_start) / jnp.maximum(decay_steps, 1),
                        0, 1)
    # exponential decay to final_frac over the decay window
    decay = peak_lr * jnp.exp(jnp.log(final_frac) * progress)
    lr = jnp.where(step < warmup_steps, warm, peak_lr)
    return jnp.where(step > decay_start, decay, lr)


def get_schedule(name: str):
    return {"cosine": cosine_with_warmup, "wsd": wsd}[name]

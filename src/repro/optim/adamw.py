"""AdamW with optional 8-bit (blockwise-quantized) moments.

Functional API (no optax dependency in this offline container):
  state = init(params, cfg)
  updates, state = update(grads, state, params, lr, cfg)

8-bit moments are the distributed-optimization trick that fits the jamba-398B
optimizer state into 16 GB/chip (DESIGN.md §6): m and v are stored as int8
lattices with per-block fp32 absmax scales (block = trailing 256 elements).
The quantize/dequantize round-trip is exercised every step, matching how a
real deployment would keep the sharded state compact in HBM.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    eightbit_moments: bool = False
    moment_block: int = 256


def _blocked(x, block):
    flat = x.reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, block), pad


def _qm(x, block):
    xb, _ = _blocked(x.astype(jnp.float32), block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dq(q, scale, shape, block):
    del block
    x = (q.astype(jnp.float32) * scale).reshape(-1)
    return x[:_numel(shape)].reshape(shape)


def _numel(shape):
    n = 1
    for s in shape:
        n *= s
    return n


def init(params, cfg: AdamWConfig):
    def zero_like(p):
        if cfg.eightbit_moments:
            q, scale = _qm(jnp.zeros(p.shape, jnp.float32), cfg.moment_block)
            return {"q": q, "scale": scale}
        return jnp.zeros(p.shape, jnp.float32)

    moments = lambda: jax.tree.map(zero_like, params)
    return {"m": moments(), "v": moments(),
            "count": jnp.zeros((), jnp.int32)}


def update(grads, state, params, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    b1c = 1.0 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** count.astype(jnp.float32)

    def upd(g, m_st, v_st, p):
        g = g.astype(jnp.float32)
        if cfg.eightbit_moments:
            m_prev = _dq(m_st["q"], m_st["scale"], p.shape, cfg.moment_block)
            v_prev = _dq(v_st["q"], v_st["scale"], p.shape, cfg.moment_block)
        else:
            m_prev, v_prev = m_st, v_st
        m = cfg.b1 * m_prev + (1 - cfg.b1) * g
        v = cfg.b2 * v_prev + (1 - cfg.b2) * g * g
        mhat = m / b1c
        vhat = v / b2c
        step = mhat / (jnp.sqrt(vhat) + cfg.eps)
        step = step + cfg.weight_decay * p.astype(jnp.float32)
        if cfg.eightbit_moments:
            mq, ms = _qm(m, cfg.moment_block)
            vq, vs = _qm(v, cfg.moment_block)
            return -lr * step, {"q": mq, "scale": ms}, {"q": vq, "scale": vs}
        return -lr * step, m, v

    def _is_moment(x):
        # 8-bit moment leaves are exactly {"q": int8, "scale": f32} dicts;
        # (note attention param blocks also contain a "q" key — match the
        # full key set, not membership)
        return isinstance(x, dict) and set(x.keys()) == {"q", "scale"}

    flat_u = jax.tree.map(upd, grads, state["m"], state["v"], params,
                          is_leaf=_is_moment)
    # unzip the 3-tuples
    updates = jax.tree.map(lambda t: t[0], flat_u,
                           is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat_u,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat_u,
                         is_leaf=lambda x: isinstance(x, tuple))
    return updates, {"m": new_m, "v": new_v, "count": count}


def apply_updates(params, updates):
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u).astype(p.dtype),
        params, updates)


def global_norm(tree):
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    factor = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * factor, grads), norm

"""Paged KV-cache bookkeeping: page pool, block tables, prefix sharing.

The serving cache used to be slot-contiguous — every admitted sequence
reserved ``max_len`` rows of ``[B, S, KVH, ...]`` up front, so identical
system-prompt prefixes were stored B times and short requests stranded
most of their reservation.  This module re-lays the (possibly sub-byte
packed) cache as a **pool of fixed-size pages** indexed through per-slot
block tables (DESIGN.md §18):

* :class:`PagePool` owns the physical pages: a free list, per-page
  refcounts, and a radix-style prefix index that hash-conses token-id
  prefixes (one node per page, keyed by its token tuple under its
  parent) so requests sharing a prompt prefix share physical pages.
* Block tables are plain host-side ``np.int32 [B, pages_per_slot]``
  arrays owned by the engine; the pool only tracks which pages they
  reference (refcounts), never the tables themselves — tables travel as
  ordinary step arguments and replicate under a mesh.
* Copy-on-write: a page referenced by more than one table entry — or
  frozen immutable by the prefix index — is copied before a slot writes
  into it (:func:`copy_page` does the whole-page device copy across all
  attention layers' pools).
* Eviction is page-level: retiring a slot only drops its references;
  pages held by the prefix index stay cached (a warm prefix cache) until
  allocation pressure evicts idle leaves LRU-first.

Sub-byte wrinkle (the reason this is not a datastructure drop-in): for
``kv_bits`` in {4, 2} the cache stores bit-dense int32 words
(``32 // kv_bits`` values per word, ``packing.LAYOUT_FAMILY``), so
``page_size`` must be a multiple of that word-packing tail — every page
then holds whole words and is independently quantizable/dequantizable,
and the per-(pos, kv-head) scale planes page alongside the words
(:func:`validate_page_size`).
"""

from __future__ import annotations

import dataclasses
import itertools

import numpy as np

__all__ = ["PagePool", "copy_page", "page_granularity", "validate_page_size"]


def page_granularity(kv_bits: int) -> int:
    """Token-count granularity a page must respect for ``kv_bits``.

    Sub-byte caches store ``32 // kv_bits`` values per int32 word
    (attention._kv_quantize via packing.pack_words), so pages sized to a
    multiple of that tail always hold whole packed words — vector-lane
    loads over page rows never straddle a page boundary and each page
    dequantizes independently.  bf16 / int8 layouts have no tail (1).
    """
    return 32 // kv_bits if kv_bits in (4, 2) else 1


def validate_page_size(page_size: int, kv_bits: int) -> None:
    """Raise unless ``page_size`` respects the word-packing tail."""
    if page_size < 1:
        raise ValueError(f"page_size must be >= 1, got {page_size}")
    g = page_granularity(kv_bits)
    if page_size % g:
        raise ValueError(
            f"page_size {page_size} is not a multiple of the {kv_bits}-bit "
            f"word-packing tail ({g} values per int32 word, "
            f"packing.LAYOUT_FAMILY); pages must hold whole packed words "
            f"to stay independently dequantizable (DESIGN.md §18)")


def copy_page(caches, src: int, dst: int):
    """Copy physical page ``src`` -> ``dst`` in every attn pool leaf.

    The COW primitive: one whole-page device copy per (layer, leaf) —
    words and their scale planes move together, so the copy is exact at
    any ``kv_bits``.  Non-attention sub-caches (mamba/xLSTM states) are
    per-slot, not paged, and pass through untouched.
    """
    out = []
    for layer in caches:
        layer = dict(layer)
        sub = layer.get("attn")
        if isinstance(sub, dict):
            layer["attn"] = {k: v.at[dst].set(v[src])
                             for k, v in sub.items()}
        out.append(layer)
    return out


@dataclasses.dataclass
class _Node:
    """One cached prefix page: ``tokens`` (<= page_size ids) stored at
    physical page ``page``, chained under ``parent`` (None = root).
    Only full pages carry children — a partial tail is a leaf, because
    positions past its token count are unwritten."""

    tokens: tuple
    page: int
    parent: "_Node | None"
    children: dict = dataclasses.field(default_factory=dict)
    stamp: int = 0


class PagePool:
    """Refcounted page pool + radix-style prefix index (DESIGN.md §18;
    the module docstring carries the layout story).

    Units: ``page_size`` counts token *rows*, not bytes, and must pass
    :func:`validate_page_size` — a multiple of the sub-byte word-packing
    granularity (8 rows at 4-bit KV, 16 at 2-bit) so every page holds
    whole packed words and dequantizes independently.

    Refcount convention: ``alloc`` hands pages out at ref 1 (the caller's
    block-table reference); ``retain``/``release`` adjust for sharing; a
    page registered in the prefix index holds one extra ref and is marked
    immutable, so it survives slot retirement (ref >= 1) and any writer
    must COW first.  ``ref == 0`` returns the page to the free list.
    """

    def __init__(self, num_pages: int, page_size: int, kv_bits: int = 0):
        validate_page_size(page_size, kv_bits)
        if num_pages < 1:
            raise ValueError(f"num_pages must be >= 1, got {num_pages}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        self.kv_bits = int(kv_bits)
        self.ref = np.zeros(self.num_pages, np.int64)
        self._immutable = np.zeros(self.num_pages, bool)
        self._free = list(range(self.num_pages - 1, -1, -1))
        self._top: dict = {}                 # root children: tokens -> _Node
        self._node_of_page: dict[int, _Node] = {}
        self._clock = itertools.count(1)
        # counters surfaced through capacity_report (DESIGN.md §18)
        self.prefix_hits = 0
        self.prefix_hit_tokens = 0
        self.cow_copies = 0
        self.evicted_pages = 0

    # ------------------------------------------------------------------
    # Physical pages
    # ------------------------------------------------------------------

    def alloc(self, n: int) -> list[int] | None:
        """Take ``n`` pages at ref 1, evicting idle prefix leaves
        (LRU-first) under pressure.  All-or-nothing: returns None — with
        nothing taken — when even eviction cannot satisfy the request,
        so admission can simply leave the request queued."""
        out: list[int] = []
        while len(out) < n:
            if not self._free and not self._evict_one():
                for p in out:
                    self.ref[p] = 0
                    self._free.append(p)
                return None
            p = self._free.pop()
            self.ref[p] = 1
            self._immutable[p] = False
            out.append(p)
        return out

    def retain(self, page: int) -> None:
        self.ref[page] += 1

    def release(self, page: int) -> None:
        self.ref[page] -= 1
        if self.ref[page] < 0:
            raise RuntimeError(f"page {page} over-released")
        if self.ref[page] == 0:
            self._immutable[page] = False
            self._free.append(page)

    def is_shared(self, page: int) -> bool:
        return bool(self.ref[page] > 1)

    def is_immutable(self, page: int) -> bool:
        return bool(self._immutable[page])

    def _evict_one(self) -> bool:
        """Drop the least-recently-touched idle prefix leaf (ref == 1:
        only the index holds it).  A leaf still shared with a live slot
        (ref > 1) is skipped — and keeps its ancestors pinned, since
        evicting a parent would strand reachable descendants."""
        victim = None
        for node in self._node_of_page.values():
            if node.children or self.ref[node.page] != 1:
                continue
            if victim is None or node.stamp < victim.stamp:
                victim = node
        if victim is None:
            return False
        parent_children = (victim.parent.children if victim.parent
                           else self._top)
        del parent_children[victim.tokens]
        del self._node_of_page[victim.page]
        self.evicted_pages += 1
        self.release(victim.page)            # index ref -> free list
        return True

    # ------------------------------------------------------------------
    # Prefix index (radix over token-id pages)
    # ------------------------------------------------------------------

    def match_prefix(self, tokens, max_tokens: int | None = None):
        """Longest cached prefix of ``tokens`` -> (n_matched, pages).

        ``pages`` is ``[(page, rows_used)]`` covering tokens
        ``0..n_matched-1`` in order; full-page matches descend the radix
        chain, a partial match (against a full page's head or a partial
        tail leaf) ends the walk.  The caller retains every returned
        page before using it.  ``max_tokens`` caps the match (admission
        passes ``len(prompt) - 1`` so the last prompt token — whose
        logits seed generation — is always computed, never skipped).
        """
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        if max_tokens is not None:
            toks = toks[:max_tokens]
        ps = self.page_size
        pages: list[tuple[int, int]] = []
        children = self._top
        n = 0
        while n < len(toks):
            chunk = tuple(toks[n:n + ps])
            node = children.get(chunk) if len(chunk) == ps else None
            if node is not None:             # whole page matches: descend
                self._touch(node)
                pages.append((node.page, ps))
                n += ps
                children = node.children
                continue
            best, blen = None, 0
            for ctoks, cnode in children.items():
                m = 0
                for a, b in zip(ctoks, chunk):
                    if a != b:
                        break
                    m += 1
                if m > blen:
                    best, blen = cnode, m
            if blen:
                self._touch(best)
                pages.append((best.page, blen))
                n += blen
            break                            # divergence (or exhausted)
        return n, pages

    def register_prefix(self, tokens, pages) -> int:
        """Hash-cons ``tokens`` (a completed prompt) into the index.

        ``pages[i]`` is the slot's physical page holding token rows
        ``i*page_size..`` — full pages plus the partial tail.  Chunks
        already cached are skipped (the existing node keeps serving
        matches; the duplicate page stays slot-owned and frees at
        retirement).  New nodes retain their page and freeze it
        immutable; the owning slot's next write into a registered page
        (its first generated token landing in the prompt's tail page)
        copy-on-writes — that is the divergence case.  Returns the
        number of pages newly registered.
        """
        toks = [int(t) for t in np.asarray(tokens).reshape(-1)]
        ps = self.page_size
        children = self._top
        parent = None
        added = 0
        for i, start in enumerate(range(0, len(toks), ps)):
            chunk = tuple(toks[start:start + ps])
            node = children.get(chunk)
            if node is None:
                page = int(pages[i])
                node = _Node(tokens=chunk, page=page, parent=parent)
                children[chunk] = node
                self._node_of_page[page] = node
                self.retain(page)
                self._immutable[page] = True
                added += 1
            self._touch(node)
            if len(chunk) < ps:
                break                        # partial tail is a leaf
            parent = node
            children = node.children
        return added

    def _touch(self, node: _Node) -> None:
        node.stamp = next(self._clock)

    # ------------------------------------------------------------------
    # Accounting / serialization
    # ------------------------------------------------------------------

    def report(self) -> dict:
        """Physical-vs-logical page counters for ``capacity_report``."""
        free = len(self._free)
        return {
            "free_pages": free,
            "live_pages": self.num_pages - free,
            "shared_pages": int((self.ref > 1).sum()),
            "cached_prefix_pages": len(self._node_of_page),
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "evicted_pages": self.evicted_pages,
        }

    def export_meta(self) -> dict:
        """JSON-able pool state (checkpoint manifest `extra`): refcounts,
        free list, immutability, and the prefix index as a parent-before-
        child node list keyed by page id (drain/restore, DESIGN.md §18)."""
        nodes = []

        def walk(children):
            for node in children.values():
                nodes.append({
                    "tokens": list(node.tokens),
                    "page": int(node.page),
                    "parent_page": (None if node.parent is None
                                    else int(node.parent.page)),
                    "stamp": int(node.stamp),
                })
                walk(node.children)

        walk(self._top)
        return {
            "num_pages": self.num_pages,
            "page_size": self.page_size,
            "kv_bits": self.kv_bits,
            "ref": [int(r) for r in self.ref],
            "immutable": [bool(b) for b in self._immutable],
            "free": [int(p) for p in self._free],
            "nodes": nodes,
            "prefix_hits": self.prefix_hits,
            "prefix_hit_tokens": self.prefix_hit_tokens,
            "cow_copies": self.cow_copies,
            "evicted_pages": self.evicted_pages,
        }

    @classmethod
    def from_meta(cls, meta: dict) -> "PagePool":
        pool = cls(meta["num_pages"], meta["page_size"],
                   meta.get("kv_bits", 0))
        pool.ref = np.asarray(meta["ref"], np.int64).copy()
        pool._immutable = np.asarray(meta["immutable"], bool).copy()
        pool._free = [int(p) for p in meta["free"]]
        by_page: dict[int, _Node] = {}
        max_stamp = 0
        for rec in meta["nodes"]:            # parents precede children
            parent = (None if rec["parent_page"] is None
                      else by_page[rec["parent_page"]])
            node = _Node(tokens=tuple(rec["tokens"]), page=rec["page"],
                         parent=parent, stamp=rec.get("stamp", 0))
            (parent.children if parent else pool._top)[node.tokens] = node
            by_page[node.page] = node
            max_stamp = max(max_stamp, node.stamp)
        pool._node_of_page = by_page
        pool._clock = itertools.count(max_stamp + 1)
        pool.prefix_hits = int(meta.get("prefix_hits", 0))
        pool.prefix_hit_tokens = int(meta.get("prefix_hit_tokens", 0))
        pool.cow_copies = int(meta.get("cow_copies", 0))
        pool.evicted_pages = int(meta.get("evicted_pages", 0))
        return pool

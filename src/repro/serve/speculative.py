"""Speculative decoding with a sub-byte draft model (DESIGN.md §19).

The paper's W2A2 packed kernels run ~3.2x faster than the 16-bit baseline
on the same substrate; this module turns that footprint/throughput win
into a decode-latency win.  A second copy of the SAME checkpoint is
packed at ``draft_w_bits`` (2-bit by default, ~1/8 the bytes of 8-bit)
and drafts ``k`` greedy tokens per slot in one launch
(launch/steps.make_draft_step); the target model then scores the whole
drafted chain in ONE ``[B, k+1]`` chunked call
(launch/steps.make_verify_chunk_step — the prefill-chunk window shape of
PR 2, returning every position's logits).  Host-side rejection sampling
commits the longest target-faithful prefix.

Correctness (the rejection rule, greedy-draft / delta-proposal form):
the draft proposes ``d`` deterministically, i.e. proposal q = delta_d.
Accept ``d`` with probability ``p(d)`` where ``p`` is the TARGET
distribution after the slot's temperature/top-k transform (`probs_for`,
the same transform engine sampling uses).  On rejection, resample from
``p`` with ``d`` masked out, renormalized.  The committed token's
marginal is then  p(d)·1[t=d] + (1-p(d))·p(t)/(1-p(d))·1[t≠d] = p(t)
for every t — exactly target-only sampling, so speculative decoding
changes throughput, never the output distribution.  At temperature 0 the
rule degenerates to argmax equality and the output is token-for-token
identical to plain decode.  When all ``k`` drafts are accepted, the
verify window's last row is a free (k+1)-th distribution — the bonus
token — so a cycle commits between 1 and k+1 tokens.

Cache bookkeeping: verify writes K/V for positions
``pos .. pos + limit`` with the usual valid-prefix gating; chunked
writes equal sequential writes (PR 2), so the accepted prefix's rows are
already exact and the rejected suffix is stale garbage that attention
masks until a later pass overwrites it — rollback is simply not
advancing ``slot_pos``.  The draft keeps its own caches (and, paged, its
own small page pool sized ``max_batch × pages_per_slot`` with no prefix
sharing: drafts always replay the full prompt, because a target-side
prefix skip would leave the draft cache without those rows).
"""

from __future__ import annotations

import numpy as np

from repro.serve.config import EngineConfig, SamplingParams

__all__ = ["DraftModel", "accept_tokens", "draft_model_config",
           "probs_for", "sample_token"]


# ---------------------------------------------------------------------------
# Sampling math (shared with ServingEngine._sample)
# ---------------------------------------------------------------------------

def probs_for(logits_row, sp: SamplingParams) -> np.ndarray:
    """The slot's target distribution: temperature / top-k transform of
    one logits row, in float64 (host-side, deterministic across
    platforms).  This is THE transform engine sampling applies, factored
    out so accept/reject scores drafts against exactly the distribution
    plain decode would have sampled from.  Greedy (temperature <= 0) has
    no distribution — callers special-case argmax."""
    scaled = np.asarray(logits_row, np.float64) / max(sp.temperature, 1e-6)
    if sp.top_k > 0:
        kk = min(sp.top_k, scaled.size)
        kth = np.partition(scaled, -kk)[-kk]
        scaled = np.where(scaled < kth, -np.inf, scaled)
    scaled = scaled - scaled.max()
    probs = np.exp(scaled)
    probs /= probs.sum()
    return probs


def sample_token(logits_row, sp: SamplingParams, rng) -> int:
    """Sample one token (greedy / temperature / top-k) from a logits row
    with the slot's numpy Generator — the single sampling primitive both
    plain decode and the speculative bonus/resample path go through."""
    if sp.greedy:
        return int(np.argmax(np.asarray(logits_row, np.float64)))
    probs = probs_for(logits_row, sp)
    return int(rng.choice(len(probs), p=probs))


def accept_tokens(window_logits, drafted, sp: SamplingParams,
                  rng) -> list[int]:
    """Rejection-sample one speculative cycle for one slot.

    ``window_logits`` [w, vocab] are the verify pass's full-window rows,
    ``w == len(drafted) + 1``: row ``i`` is the target distribution for
    the token FOLLOWING the i-th window token, i.e. it scores
    ``drafted[i]``; the last row is the bonus distribution used only
    when every draft is accepted.  Returns the committed tokens, length
    1..w: accepted drafts, then exactly one target-sampled token (the
    rejection resample, or the bonus).  ``len(result) - 1`` drafts were
    accepted — the Metrics acceptance counter.
    """
    out: list[int] = []
    for i, d in enumerate(drafted):
        d = int(d)
        row = window_logits[i]
        if sp.greedy:
            t = int(np.argmax(np.asarray(row, np.float64)))
            out.append(t)
            if t != d:
                return out
            continue
        p = probs_for(row, sp)
        if rng.random() < p[d]:
            out.append(d)
            continue
        q = p.copy()
        q[d] = 0.0
        tot = q.sum()
        if tot <= 0.0:
            # p was numerically a point mass on d; rejection then had
            # probability ~0 — committing d keeps the marginal exact
            out.append(d)
            continue
        out.append(int(rng.choice(len(q), p=q / tot)))
        return out
    out.append(sample_token(window_logits[len(drafted)], sp, rng))
    return out


# ---------------------------------------------------------------------------
# Draft config + per-engine draft state
# ---------------------------------------------------------------------------

def draft_model_config(cfg, econf: EngineConfig):
    """The draft model's config: the target config with its quantization
    dropped to ``draft_w_bits`` (weights AND activations — the paper's
    symmetric fast corner; W2A2 by default) and, optionally,
    ``draft_kv_bits`` for the draft KV cache.  Lane-layout fields reset
    to the int16 x2 default so the draft packs under a layout that is
    always feasible at sub-byte widths.  On an unpacked (or
    quant-disabled) engine the draft IS the target config: same float
    params, and the speculative win reduces to launch amortization.
    """
    q = cfg.quant
    if not (econf.packed and q.enabled):
        return cfg
    kv = q.kv_bits if econf.draft_kv_bits is None else econf.draft_kv_bits
    dq = q.replace(w_bits=econf.draft_w_bits,
                   a_bits=min(q.a_bits, econf.draft_w_bits),
                   kv_bits=kv,
                   lane_dtype="int16", n_pack=2, pack_shift=None)
    return cfg.replace(quant=dq)


class DraftModel:
    """Draft-side serving state for one :class:`ServingEngine`.

    Owns the re-packed draft param tree, its KernelPlans, its caches,
    and — paged — its own page pool and block tables.  The pool is
    sized ``max_batch × pages_per_slot`` (worst case, no sharing), so a
    draft reservation can never fail after the target's succeeded; at
    2-bit KV that worst case costs ~1/8 of the equivalent bf16 pool
    (DESIGN.md §19 sizing math).  Per-slot state: ``fed`` (prompt tokens
    the draft has consumed — the draft replays the FULL prompt even when
    the target prefix-skips) and the stashed first-token logits for
    slots whose target finished prefilling before the draft did.
    """

    def __init__(self, cfg, raw_params, econf: EngineConfig, *,
                 max_batch: int, max_len: int, shard_plan=None, mesh=None,
                 tp_axis=None):
        from repro.launch import steps as steps_lib
        from repro.models import lm
        from repro.serve import pages as pages_lib
        from repro.serve.prepare import (build_layer_plans,
                                         prepare_serving_params)

        self.k = econf.speculative_k
        self.cfg = draft_model_config(cfg, econf)
        self.max_batch = max_batch
        self.max_len = max_len
        packed = econf.packed and self.cfg.quant.enabled
        self.packed = packed
        # Re-pack the SAME checkpoint at the draft precision.  recalibrate
        # drops the QAT-learned step sizes (calibrated for the target
        # bits) so absmax re-derives scales for the draft grid — but only
        # when the grids actually differ: at matching bit widths the
        # learned steps are already the right ones, and keeping them
        # makes the draft numerically the target (acceptance ~1).
        recalib = (self.cfg.quant.w_bits != cfg.quant.w_bits
                   or self.cfg.quant.a_bits != cfg.quant.a_bits)
        self.params = prepare_serving_params(
            raw_params, self.cfg, dense_store=econf.dense_store,
            autotune=econf.autotune, recalibrate=recalib) \
            if packed else raw_params
        self.plans = build_layer_plans(
            self.params, self.cfg, batch_rows=max_batch,
            prefill_rows=max_batch * econf.prefill_chunk,
            autotune=econf.autotune,
            shard_plan=shard_plan) if packed else {}
        if shard_plan is not None:
            self.params = shard_plan.place_params(self.params)
        self._draft, _ = steps_lib.jitted_speculative_steps(
            cfg, self.cfg, self.k, kv_shard_axis=tp_axis, mesh=mesh)
        # draft prefill reuses the ordinary chunked-prefill step (logits
        # discarded) — memoized per draft config like any serving step
        _, self._prefill = steps_lib.jitted_serving_steps(
            self.cfg, kv_shard_axis=tp_axis, mesh=mesh)
        self.paged = econf.paged
        kv_bits = getattr(self.cfg.quant, "kv_bits", 0)
        if self.paged:
            pages_lib.validate_page_size(econf.page_size, kv_bits)
            self.page_size = econf.page_size
            self.pages_per_slot = -(-max_len // econf.page_size)
            self.num_pages = max_batch * self.pages_per_slot
            self.page_bytes = lm.cache_page_bytes(self.cfg, self.page_size)
            self.caches = lm.init_caches(self.cfg, max_batch, max_len,
                                         page_size=self.page_size,
                                         num_pages=self.num_pages)
            self.pool = pages_lib.PagePool(self.num_pages, self.page_size,
                                           kv_bits)
            self.block_tables = np.zeros((max_batch, self.pages_per_slot),
                                         np.int32)
            self._extent = [0] * max_batch
        else:
            self.caches = lm.init_caches(self.cfg, max_batch, max_len)
        if shard_plan is not None:
            self.caches = shard_plan.place_caches(
                self.caches, self.cfg, max_batch, paged=self.paged)
        self.fed = np.zeros(max_batch, np.int32)
        self._stash: dict[int, np.ndarray] = {}

    # -- per-slot lifecycle --------------------------------------------

    def begin_slot(self, slot: int, req) -> None:
        """Reset draft bookkeeping at admission and, paged, reserve the
        slot's full write extent (guaranteed to succeed — pool sizing)."""
        self.fed[slot] = 0
        self._stash.pop(slot, None)
        if self.paged:
            written = len(req.prompt) + req.max_new_tokens - 1
            n_pages = -(-written // self.page_size)
            got = self.pool.alloc(n_pages)
            if got is None:  # unreachable by sizing; fail loudly if not
                raise RuntimeError(
                    f"draft page pool exhausted for slot {slot}: asked "
                    f"{n_pages} of {self.num_pages} pages")
            table = self.block_tables[slot]
            table[:] = 0
            table[:n_pages] = got
            self._extent[slot] = n_pages

    def release_slot(self, slot: int) -> None:
        self._stash.pop(slot, None)
        if self.paged:
            for p in self.block_tables[slot][:self._extent[slot]]:
                self.pool.release(int(p))
            self.block_tables[slot][:] = 0
            self._extent[slot] = 0

    # -- prompt stash (target prefix-skipped ahead of the draft) --------

    def prompt_done(self, slot: int, req) -> bool:
        return int(self.fed[slot]) >= len(req.prompt)

    def stash(self, slot: int, logits_row: np.ndarray) -> None:
        self._stash[slot] = logits_row

    def pop_stash(self, slot: int):
        return self._stash.pop(slot, None)

    def has_stash(self, slot: int) -> bool:
        return slot in self._stash

    # -- reporting ------------------------------------------------------

    def describe(self) -> dict:
        """capacity_report section: draft precision + pool sizing."""
        rep = {
            "speculative_k": self.k,
            "draft_w_bits": self.cfg.quant.w_bits if self.packed else 0,
            "draft_a_bits": self.cfg.quant.a_bits if self.packed else 0,
            "draft_kv_bits": (getattr(self.cfg.quant, "kv_bits", 0) or 16)
            if self.packed else 16,
            "draft_packed": self.packed,
        }
        if self.paged:
            rep.update(draft_num_pages=self.num_pages,
                       draft_page_bytes=self.page_bytes,
                       draft_pool_bytes=self.num_pages * self.page_bytes)
        return rep

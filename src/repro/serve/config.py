"""EngineConfig: the one serving construction surface (DESIGN.md §17).

``ServingEngine.__init__`` had grown 12 ad-hoc keywords, mirrored
flag-for-flag in launch/serve.py — two construction paths that could (and
did) drift.  :class:`EngineConfig` consolidates every engine knob into one
frozen, validated object that programmatic callers, the CLI
(:meth:`EngineConfig.from_args`), and the replica-fleet Router
(serve/router.py — which stamps the same config onto every replica)
construct identically.

Validation lives in ``__post_init__`` so a bad config fails at
construction, before any params are packed or steps jitted; the HBM
budget -> capacity math, which used to live inline in the engine
constructor, is :meth:`slots_for` (slot-contiguous caches) /
:meth:`pages_for` (paged pools, DESIGN.md §18) so the capacity rules are
testable without building an engine.

The PR 7 legacy-keyword shim (``ServingEngine(cfg, params, max_batch=4,
...)`` with a DeprecationWarning) completed its one-release grace period
and is gone: engine keywords now raise ``TypeError`` pointing at
``EngineConfig``.
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding control; temperature <= 0 means greedy."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if not math.isfinite(self.temperature):
            raise ValueError(
                f"sampling temperature must be finite, got "
                f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen construction config for one :class:`ServingEngine`.

    One field per engine knob; programmatic callers, the CLI
    (:meth:`from_args`), and the Router all construct through this class.
    Validation runs in ``__post_init__`` so a bad combination fails at
    construction, before params are packed or steps jitted.

    Field semantics (units in brackets):

    * ``max_batch`` — concurrent batch slots [sequences]; with an
      ``hbm_cache_budget`` the effective slot count is recomputed by
      :meth:`slots_for` / bounded logically under ``paged`` (DESIGN.md
      §13, §18).
    * ``max_len`` [tokens] — per-slot cache extent; every request must
      satisfy ``len(prompt) + max_new_tokens <= max_len``.
    * ``packed`` — serve through the paper's packed integer kernels
      (params converted by serve/prepare.py); ``dense_store`` selects the
      bit-dense int32-word weight layout and requires ``packed``.
    * ``prefill_chunk`` [tokens] — chunked-prefill window width
      (DESIGN.md §12); sliding-window configs force 1 at engine init.
    * ``max_queue`` — backpressure cap on queued requests (None =
      unbounded); under a fleet a full replica queue spills to the
      Router.
    * ``hbm_cache_budget`` [bytes] — KV-cache budget converted to slots
      (:meth:`slots_for`) or pages (:meth:`pages_for`).
    * ``paged`` / ``page_size`` / ``prefix_sharing`` — the block-table KV
      cache of DESIGN.md §18.  Invariant: ``page_size`` must be a
      multiple of the kv-bits word-packing tail (``32 // kv_bits`` rows
      for 4/2-bit caches — serve/pages.validate_page_size), checked at
      engine init where ``kv_bits`` is known.
    * ``speculative_k`` [tokens] — >0 enables speculative decoding
      (DESIGN.md §19): every pure-decode pass drafts up to ``k`` tokens
      with a 2-bit copy of the model and verifies them in one
      ``[B, k+1]`` target call.  ``draft_w_bits`` is the draft weight
      precision; ``draft_kv_bits`` overrides the draft KV-cache
      precision (None = inherit the target's).  Both only take effect on
      a packed engine (an unpacked engine drafts with the same float
      params — still fewer launches per token).  Speculation requires a
      pure-attention decoder stack (no sliding window, no M-RoPE, not
      encoder-decoder), validated at engine init.
    """

    max_batch: int = 4
    max_len: int = 512
    packed: bool = True
    dense_store: bool = False
    prefill_chunk: int = 16
    max_queue: int | None = None
    sampling: SamplingParams = SamplingParams()
    hbm_cache_budget: int | None = None
    autotune: bool = False
    paged: bool = False
    page_size: int = 16
    prefix_sharing: bool = True
    speculative_k: int = 0
    draft_w_bits: int = 2
    draft_kv_bits: int | None = None

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be None (unbounded) or >= 1, got "
                f"{self.max_queue}")
        if self.hbm_cache_budget is not None and self.hbm_cache_budget < 1:
            raise ValueError(
                f"hbm_cache_budget must be None or a positive byte count, "
                f"got {self.hbm_cache_budget}")
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError(
                f"sampling must be a SamplingParams, got "
                f"{type(self.sampling).__name__}")
        if self.dense_store and not self.packed:
            raise ValueError(
                "dense_store selects the bit-dense packed weight layout; "
                "it requires packed=True")
        if self.autotune and not self.packed:
            raise ValueError(
                "autotune warm-tunes the packed kernel signatures; it "
                "requires packed=True")
        if self.page_size < 1:
            raise ValueError(
                f"page_size must be >= 1, got {self.page_size}")
        if self.speculative_k < 0:
            raise ValueError(
                f"speculative_k must be >= 0 (0 = off), got "
                f"{self.speculative_k}")
        if self.speculative_k:
            if self.draft_w_bits not in (1, 2, 3, 4):
                raise ValueError(
                    f"draft_w_bits must be a packable sub-byte width in "
                    f"{{1, 2, 3, 4}}, got {self.draft_w_bits}")
            if self.draft_kv_bits not in (None, 0, 2, 4, 8, 16):
                raise ValueError(
                    f"draft_kv_bits must be None (inherit target) or one "
                    f"of 0/16/8/4/2, got {self.draft_kv_bits}")

    # ------------------------------------------------------------------
    # Capacity math (moved out of ServingEngine.__init__, DESIGN.md §13)
    # ------------------------------------------------------------------

    def slots_for(self, cache_bytes_per_slot: int) -> int:
        """Admitted batch slots: the HBM-budget capacity rule.

        With no budget the requested ``max_batch`` stands; with one, the
        engine admits ``budget // bytes-per-slot`` concurrent sequences —
        quantized KV caches (cfg.quant.kv_bits in {8, 4, 2}) convert
        their byte density directly into slots.
        """
        if self.hbm_cache_budget is None:
            return self.max_batch
        slots = int(self.hbm_cache_budget // cache_bytes_per_slot)
        if slots < 1:
            raise ValueError(
                f"hbm_cache_budget {self.hbm_cache_budget} < one slot's "
                f"cache ({cache_bytes_per_slot} bytes at max_len "
                f"{self.max_len})")
        return slots

    def pages_for(self, page_bytes: int, pages_per_slot: int) -> int:
        """Physical page count: the paged-pool capacity rule (DESIGN.md §18).

        With no budget the pool is sized so ``max_batch`` worst-case
        (no-sharing, full-extent) slots fit; with one, the budget buys
        ``budget // bytes-per-page`` pages.  Either way the pool must hold
        at least one worst-case slot or no request could ever admit.
        """
        if self.hbm_cache_budget is None:
            return self.max_batch * pages_per_slot
        pages = int(self.hbm_cache_budget // page_bytes)
        if pages < pages_per_slot:
            raise ValueError(
                f"hbm_cache_budget {self.hbm_cache_budget} < one worst-case "
                f"slot's pages ({pages_per_slot} pages x {page_bytes} bytes "
                f"at max_len {self.max_len}, page_size {self.page_size})")
        return pages

    # ------------------------------------------------------------------
    # Construction paths
    # ------------------------------------------------------------------

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        """Build from the launch/serve.py argparse namespace.

        The CLI derives its engine side through exactly this method, so
        flag surface and programmatic construction cannot drift — adding
        an engine knob means adding a field here and a flag in the CLI's
        ``engine``/``sampling`` groups, nothing else.
        """
        mb = getattr(args, "hbm_cache_budget_mb", None)
        if mb is None or mb <= 0:
            # 0 / negative are the CLI's "no budget" sentinels.  The old
            # expression `int(mb * 2**20) or None` made any sub-megabyte
            # budget that truncated to 0 bytes silently mean "unlimited";
            # now only explicit non-positive values do.
            budget = None
        else:
            budget = int(mb * 2**20)
            if budget < 1:
                raise ValueError(
                    f"--hbm-cache-budget-mb {mb} is positive but rounds to "
                    f"under one byte; use 0 to disable the budget")
        return cls(
            max_batch=args.max_batch,
            max_len=args.max_len,
            packed=not args.no_packed,
            dense_store=getattr(args, "dense_store", False),
            prefill_chunk=args.prefill_chunk,
            max_queue=args.max_queue or None,
            sampling=SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k),
            hbm_cache_budget=budget,
            autotune=args.autotune,
            paged=getattr(args, "paged_kv", False),
            page_size=getattr(args, "page_size", 16),
            prefix_sharing=not getattr(args, "no_prefix_sharing", False),
            speculative_k=getattr(args, "speculative_k", 0),
            draft_w_bits=getattr(args, "draft_w_bits", 2),
            draft_kv_bits=(None if getattr(args, "draft_kv_bits", -1) < 0
                           else args.draft_kv_bits))

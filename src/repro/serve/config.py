"""EngineConfig: the one serving construction surface (DESIGN.md §17).

``ServingEngine.__init__`` had grown 12 ad-hoc keywords, mirrored
flag-for-flag in launch/serve.py — two construction paths that could (and
did) drift.  :class:`EngineConfig` consolidates every engine knob into one
frozen, validated object that programmatic callers, the CLI
(:meth:`EngineConfig.from_args`), and the replica-fleet Router
(serve/router.py — which stamps the same config onto every replica)
construct identically.

Validation lives in ``__post_init__`` so a bad config fails at
construction, before any params are packed or steps jitted; the HBM
budget -> slot-count math, which used to live inline in the engine
constructor, is :meth:`slots_for` so the capacity rule is testable without
building an engine.

Legacy keyword construction (``ServingEngine(cfg, params, max_batch=4,
...)``) still works for one release through a ``DeprecationWarning`` shim
that forwards to :meth:`from_legacy_kwargs`, which preserves the old
clamping semantics (e.g. ``prefill_chunk=0`` silently clamped to 1 where
the new validation raises).
"""

from __future__ import annotations

import dataclasses
import math


@dataclasses.dataclass(frozen=True)
class SamplingParams:
    """Per-request decoding control; temperature <= 0 means greedy."""
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0

    def __post_init__(self):
        if not math.isfinite(self.temperature):
            raise ValueError(
                f"sampling temperature must be finite, got "
                f"{self.temperature}")
        if self.top_k < 0:
            raise ValueError(f"top_k must be >= 0, got {self.top_k}")

    @property
    def greedy(self) -> bool:
        return self.temperature <= 0.0


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Frozen construction config for one :class:`ServingEngine`.

    Field mapping from the legacy keyword surface (the deprecation shim
    forwards one-to-one; migration table in DESIGN.md §17):

    ==================  =====================================
    legacy kwarg        EngineConfig field
    ==================  =====================================
    max_batch           max_batch
    max_len             max_len
    packed              packed
    greedy              folded into ``sampling`` (greedy=False
                        became SamplingParams(temperature=1.0))
    dense_store         dense_store
    prefill_chunk       prefill_chunk (now validated >= 1)
    max_queue           max_queue
    sampling            sampling (never None; default greedy)
    hbm_cache_budget    hbm_cache_budget
    autotune            autotune
    ==================  =====================================
    """

    max_batch: int = 4
    max_len: int = 512
    packed: bool = True
    dense_store: bool = False
    prefill_chunk: int = 16
    max_queue: int | None = None
    sampling: SamplingParams = SamplingParams()
    hbm_cache_budget: int | None = None
    autotune: bool = False

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError(f"max_batch must be >= 1, got {self.max_batch}")
        if self.max_len < 1:
            raise ValueError(f"max_len must be >= 1, got {self.max_len}")
        if self.prefill_chunk < 1:
            raise ValueError(
                f"prefill_chunk must be >= 1, got {self.prefill_chunk}")
        if self.max_queue is not None and self.max_queue < 1:
            raise ValueError(
                f"max_queue must be None (unbounded) or >= 1, got "
                f"{self.max_queue}")
        if self.hbm_cache_budget is not None and self.hbm_cache_budget < 1:
            raise ValueError(
                f"hbm_cache_budget must be None or a positive byte count, "
                f"got {self.hbm_cache_budget}")
        if not isinstance(self.sampling, SamplingParams):
            raise TypeError(
                f"sampling must be a SamplingParams, got "
                f"{type(self.sampling).__name__}")
        if self.dense_store and not self.packed:
            raise ValueError(
                "dense_store selects the bit-dense packed weight layout; "
                "it requires packed=True")
        if self.autotune and not self.packed:
            raise ValueError(
                "autotune warm-tunes the packed kernel signatures; it "
                "requires packed=True")

    # ------------------------------------------------------------------
    # Capacity math (moved out of ServingEngine.__init__, DESIGN.md §13)
    # ------------------------------------------------------------------

    def slots_for(self, cache_bytes_per_slot: int) -> int:
        """Admitted batch slots: the HBM-budget capacity rule.

        With no budget the requested ``max_batch`` stands; with one, the
        engine admits ``budget // bytes-per-slot`` concurrent sequences —
        quantized KV caches (cfg.quant.kv_bits in {8, 4, 2}) convert
        their byte density directly into slots.
        """
        if self.hbm_cache_budget is None:
            return self.max_batch
        slots = int(self.hbm_cache_budget // cache_bytes_per_slot)
        if slots < 1:
            raise ValueError(
                f"hbm_cache_budget {self.hbm_cache_budget} < one slot's "
                f"cache ({cache_bytes_per_slot} bytes at max_len "
                f"{self.max_len})")
        return slots

    # ------------------------------------------------------------------
    # Construction paths
    # ------------------------------------------------------------------

    @classmethod
    def from_args(cls, args) -> "EngineConfig":
        """Build from the launch/serve.py argparse namespace.

        The CLI derives its engine side through exactly this method, so
        flag surface and programmatic construction cannot drift — adding
        an engine knob means adding a field here and a flag in the CLI's
        ``engine``/``sampling`` groups, nothing else.
        """
        return cls(
            max_batch=args.max_batch,
            max_len=args.max_len,
            packed=not args.no_packed,
            dense_store=getattr(args, "dense_store", False),
            prefill_chunk=args.prefill_chunk,
            max_queue=args.max_queue or None,
            sampling=SamplingParams(temperature=args.temperature,
                                    top_k=args.top_k),
            hbm_cache_budget=int(args.hbm_cache_budget_mb * 2**20) or None,
            autotune=args.autotune)

    @classmethod
    def from_legacy_kwargs(cls, *, max_batch: int = 4, max_len: int = 512,
                           packed: bool = True, greedy: bool = True,
                           dense_store: bool = False,
                           prefill_chunk: int = 16,
                           max_queue: int | None = None,
                           sampling: SamplingParams | None = None,
                           hbm_cache_budget: int | None = None,
                           autotune: bool = False) -> "EngineConfig":
        """The deprecation shim's target: old keyword surface, old
        semantics (``greedy`` folded into sampling, ``prefill_chunk``
        clamped instead of rejected).  Unknown keywords raise TypeError
        at the call boundary exactly as the old signature did."""
        if sampling is None:
            sampling = SamplingParams(temperature=0.0 if greedy else 1.0)
        return cls(
            max_batch=max_batch, max_len=max_len, packed=packed,
            dense_store=dense_store,
            prefill_chunk=max(1, int(prefill_chunk)),
            max_queue=max_queue, sampling=sampling,
            hbm_cache_budget=hbm_cache_budget, autotune=autotune)

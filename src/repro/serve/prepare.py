"""Offline conversion of trained/QAT params into deployed Sparq serving form.

Every quantizable 2-D Dense ({kernel, w_step, a_step}) becomes its packed
integer representation ({w_packed, col_sums, scales, zero-points}) via
core.common.pack_dense_params.  MoE expert tensors (3-D) and embeddings keep
fake-quant serving (DESIGN.md §5).  Optionally weights are ALSO bit-dense
stored (ops.dense_store_weights) for the decode memory-bound path.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.models import common


def _is_packable(node) -> bool:
    return (isinstance(node, dict) and "kernel" in node and "w_step" in node
            and hasattr(node["kernel"], "ndim") and node["kernel"].ndim == 2)


def prepare_serving_params(params, cfg):
    """Recursively pack all quantizable Dense leaves."""
    if not cfg.quant.enabled:
        return params

    def walk(node):
        if _is_packable(node):
            return common.pack_dense_params(node, cfg.quant)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(params)


def serving_param_bytes(params) -> int:
    """HBM bytes of a serving param tree (for the memory roofline term)."""
    import jax
    total = 0
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total

"""Offline conversion of trained/QAT params into deployed Sparq serving form.

Every quantizable 2-D Dense ({kernel, w_step, a_step}) becomes its packed
integer representation ({w_packed, col_sums, scales, zero-points}) via
core.common.pack_dense_params.  MoE expert tensors (3-D) and embeddings keep
fake-quant serving (DESIGN.md §5).  With ``dense_store=True`` weights are
instead bit-dense stored (ops.dense_store_weights, key ``w_dense``) for the
decode memory-bound path.

``build_layer_plans`` builds the per-layer KernelPlans for the packed tree
once, offline (paper §IV: the execution plan is fixed before serving) — the
serving engine calls it at init for both serving row counts (decode batch
and chunked-prefill batch x chunk) and keeps the result for reporting; the
memoized planners guarantee the same plan objects are the ones the jitted
decode and prefill-chunk steps dispatch through.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib
from repro.models import common


def _is_packable(node) -> bool:
    return (isinstance(node, dict) and "kernel" in node and "w_step" in node
            and hasattr(node["kernel"], "ndim") and node["kernel"].ndim == 2)


def _is_packed(node) -> bool:
    return isinstance(node, dict) and ("w_packed" in node or "w_dense" in node)


def prepare_serving_params(params, cfg, *, dense_store: bool = False,
                           autotune: bool = False, tune_rows: int = 8,
                           recalibrate: bool = False):
    """Recursively pack all quantizable Dense leaves.

    ``autotune=True`` sweeps the lane-layout family per distinct (k, n)
    *before* packing (autotune.tune_matmul_layout at ``tune_rows`` rows) —
    weights pack once offline, so the layout decision must be weighed here;
    pack_dense_params then resolves each layer's chosen spec from the same
    cache, and build_layer_plans / dispatch resolve identically later.

    ``recalibrate=True`` drops each leaf's learned ``w_step``/``a_step``
    before packing so scales re-derive (absmax / qmax default) for
    ``cfg.quant``'s bit widths — the speculative-draft repack path
    (DESIGN.md §19), where the SAME checkpoint packs at a lower precision
    than its QAT steps were calibrated for.
    """
    if not cfg.quant.enabled:
        return params
    store = "dense" if dense_store else "lanes"

    def walk(node):
        if _is_packable(node):
            if autotune:
                from repro.kernels import autotune as autotune_lib
                k, n = node["kernel"].shape
                autotune_lib.tune_matmul_layout(
                    tune_rows, int(k), int(n),
                    PackSpec.from_config(cfg.quant), weight_store=store)
            if recalibrate:
                node = {k: v for k, v in node.items()
                        if k not in ("w_step", "a_step")}
            return common.pack_dense_params(node, cfg.quant,
                                            dense_store=dense_store)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, list):
            return [walk(v) for v in node]
        if isinstance(node, tuple):
            return tuple(walk(v) for v in node)
        return node

    return walk(params)


def build_layer_plans(params, cfg, *, batch_rows: int = 1,
                      prefill_rows: int | None = None,
                      backend: str = "auto", autotune: bool = False,
                      shard_plan=None):
    """One KernelPlan per packed Dense leaf, keyed by its tree path.

    ``batch_rows`` is the decode-time row count (engine batch);
    ``prefill_rows`` (engine batch x prefill chunk) additionally plans the
    chunked-prefill shapes under a ``...@prefill`` key.  Plans are
    memoized, so both jitted serving steps hit exactly these objects when
    they dispatch.  Returns {'path/to/leaf': KernelPlan}.

    ``shard_plan`` (serve/shard.ShardPlan) adds *per-shard local* planning:
    packed weights are column-parallel under the serving mesh, so what one
    device executes is [rows, kp] x [kp, n / model_shards].  The primary
    ``path`` entries plan (and with ``autotune=True`` warm-tune) that
    local matmul — per-shard VMEM working sets and the autotune-cache
    signatures a shard_map'd per-device kernel dispatch consults.  The
    GSPMD-jitted XLA serving steps, however, trace *global* operand
    shapes and re-plan through the memoized planners at trace time; for
    every leaf whose output actually shards, ``...@global`` entries
    pre-memoize (and warm-tune) exactly those signatures too, so dispatch
    still hits init-built — and, when tuned, cache-backed — plans rather
    than planning ad hoc mid-trace (DESIGN.md §15).  K is never sharded
    (word boundaries stay shard-local), so ``kp``/``k_full`` are global
    in both modes.

    ``autotune=True`` is the opt-in warm-tune pass (DESIGN.md §14): every
    (rows, kp, n) signature missing from the active tuning cache is
    benchmarked once before planning, so a deployment tunes once offline
    and the plans come back cache-backed; the caller persists the cache
    via ``autotune.active_cache().save()``.
    """
    if not cfg.quant.enabled:
        return {}
    base = PackSpec.from_config(cfg.quant)
    plans = {}

    def plan_rows(rows, kp, n, dense, k_full, spec):
        store = "dense" if dense else "lanes"
        if autotune:
            from repro.kernels import autotune as autotune_lib
            autotune_lib.tune_packed_matmul(
                rows, kp, n, spec, backend=backend, weight_store=store,
                k_full=k_full)
        return plan_lib.plan_packed_matmul(
            rows, kp, n, spec, backend=backend, weight_store=store,
            k_full=k_full)

    def walk(node, path):
        if _is_packed(node):
            dense = "w_dense" in node
            w = node["w_dense"] if dense else node["w_packed"]
            n_global = int(w.shape[-1])
            n = shard_plan.local_out(n_global) if shard_plan is not None \
                else n_global
            # Per-layer chosen lane layout (DESIGN.md §16): resolve exactly
            # as pack time and dispatch time do — layout keys use the
            # logical (k, GLOBAL n); ``k_full`` is recorded in every packed
            # node so odd K resolves unambiguously.
            if dense:
                per = 32 // base.w_bits
                k = int(node.get("k_full", w.shape[0] * per))
            else:
                k = int(node.get("k_full", w.shape[0] * base.n_pack))
            spec = common.dense_layer_spec(
                k, n_global, cfg.quant,
                weight_store="dense" if dense else "lanes",
                w_packed=None if dense else w)
            if dense:
                k_full, kp = k, -(-k // spec.n_pack)
            else:
                k_full, kp = None, int(w.shape[0])
                if (w.dtype != spec.lane_dtype
                        or w.shape[0] != -(-k // spec.n_pack)):
                    raise ValueError(
                        f"{path}: packed bytes ({w.dtype}, kp={w.shape[0]}) "
                        f"do not match the resolved lane layout {spec} for "
                        f"k={k}, n={n_global}; the tree was packed under a "
                        f"different autotune layout cache — re-run "
                        f"prepare_serving_params under the active cache")
            plans[path] = plan_rows(batch_rows, kp, n, dense, k_full, spec)
            if prefill_rows and prefill_rows != batch_rows:
                plans[f"{path}@prefill"] = plan_rows(prefill_rows, kp, n,
                                                     dense, k_full, spec)
            if n != n_global:
                # GSPMD dispatch signatures (see docstring): the jitted
                # steps re-plan from global trace-time shapes, so memoize
                # + warm-tune those too
                plans[f"{path}@global"] = plan_rows(
                    batch_rows, kp, n_global, dense, k_full, spec)
                if prefill_rows and prefill_rows != batch_rows:
                    plans[f"{path}@global@prefill"] = plan_rows(
                        prefill_rows, kp, n_global, dense, k_full, spec)
            return
        if isinstance(node, dict):
            for k, v in node.items():
                walk(v, f"{path}/{k}" if path else k)
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(v, f"{path}[{i}]")

    walk(params, "")
    return plans


def cache_bytes_per_slot(cfg, max_len: int) -> int:
    """HBM bytes one batch slot's decode caches occupy at ``max_len``.

    The engine's admission-capacity term: under a fixed HBM cache budget,
    slots = budget // cache_bytes_per_slot, so a 4-bit packed KV cache
    (kv_bits=4) admits ~4x the concurrent sequences of bf16 (DESIGN.md §13).
    """
    from repro.models import lm
    return lm.cache_bytes(cfg, 1, max_len)


def cache_page_bytes(cfg, page_size: int) -> int:
    """HBM bytes one KV page (``page_size`` token rows, all attn layers)
    occupies, including its per-(pos, kv-head) scale planes.

    The paged engine's capacity term (DESIGN.md §18): under a fixed HBM
    cache budget, num_pages = budget // cache_page_bytes, and admission
    reserves pages per request rather than whole max_len slots.  Returns 0
    for attention-free stacks (nothing pageable — the engine rejects
    ``paged=True`` there).
    """
    from repro.models import lm
    return lm.cache_page_bytes(cfg, page_size)


def serving_param_bytes(params) -> int:
    """HBM bytes of a serving param tree (for the memory roofline term)."""
    import jax
    total = 0
    for leaf in jax.tree.leaves(params):
        if hasattr(leaf, "size") and hasattr(leaf, "dtype"):
            total += leaf.size * jnp.dtype(leaf.dtype).itemsize
    return total

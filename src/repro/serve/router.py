"""Replica-fleet request Router: the system's single client API.

One ``ServingEngine`` has a bounded queue; under PR 5's ``data=1`` mesh
that queue was the whole system's capacity.  The :class:`Router` scales
the front door out (DESIGN.md §17): it owns N engine replicas — replica
groups carved from a real ``('data', 'model')`` mesh via
``launch.mesh.replica_meshes`` (each replica's ShardPlan scoped to its
own ``model`` sub-axis and device group), or N process-local replicas
when no mesh is given — and clients talk only to the Router:

* **submit(prompt, sampling) -> Handle** — admission is load-balanced:
  least-loaded placement over ``queue depth + occupied slots``, ties to
  the lowest replica index (deterministic).
* **Session affinity** — a request carrying a ``session`` key pins to
  the replica that served that session before (the replica holding its
  cache slots), overriding least-loaded; the pin dissolves when the
  replica drains.
* **Per-replica backpressure -> router spillover** — a replica whose
  bounded queue is full is never offered the request (its own
  ``rejected`` counter stays a true client-visible-rejection count);
  the request waits in the Router's spillover queue and is re-placed
  FIFO as replicas free up.  TTFT clocks start at fleet admission, so
  spillover wait is part of the latency a client sees.
* **Drain / restore** — ``drain(r)`` stops admitting to replica ``r``,
  re-routes its queued-but-unadmitted requests through spillover, lets
  its live slots retire, hands its params off through the
  train/checkpoint machinery (atomic-commit manifest + per-leaf arrays),
  and detaches the engine.  ``restore(r)`` loads the checkpoint back and
  rebuilds the replica on its original mesh group — token-for-token
  identical to a never-drained replica (packing is deterministic).

Fleet ``Metrics`` extend the PR 5 report schema: per-phase tok/s summed
across replicas (replicas model disjoint hardware), TTFT/TPOT
percentiles computed over the union of per-request samples (a
percentile of per-replica percentiles would be wrong), drained replicas'
history included.
"""

from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque

import numpy as np

from repro.launch import mesh as mesh_lib
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.engine import Metrics, Request, ServingEngine
from repro.train import checkpoint


@dataclasses.dataclass
class Handle:
    """Client-side view of one fleet request (what ``submit`` returns)."""

    request: Request
    session: str | None = None
    replica: int | None = None      # set at placement; None while spilled
    spilled: bool = False           # ever waited in the spillover queue

    @property
    def uid(self) -> int:
        return self.request.uid

    @property
    def done(self) -> bool:
        return self.request.done

    @property
    def output(self) -> list:
        return list(self.request.output)


def aggregate_reports(metrics_list) -> dict:
    """Merge per-replica :class:`Metrics` into one fleet report.

    Counters sum; per-phase tok/s is the SUM of per-replica rates (each
    replica owns its devices, so fleet throughput is additive — on a
    host-simulated fleet this models disjoint hardware rather than
    measuring one box); occupancy and admission wait re-divide from the
    summed numerators; TTFT/TPOT distributions merge the raw per-request
    samples before taking percentiles.
    """
    def div(a, b):
        return a / b if b else 0.0

    ms = list(metrics_list)
    ttft = [s for m in ms for s in m.ttft_s]
    tpot = [s for m in ms for s in m.tpot_s]
    return {
        "prefill_tokens": sum(m.prefill_tokens for m in ms),
        "generated_tokens": sum(m.generated_tokens for m in ms),
        "decode_tokens": sum(m.decode_tokens for m in ms),
        "prefill_tok_s": round(sum(div(m.prefill_tokens, m.prefill_time_s)
                                   for m in ms), 1),
        "decode_tok_s": round(sum(div(m.decode_tokens, m.decode_time_s)
                                  for m in ms), 1),
        "admitted": sum(m.admitted for m in ms),
        "retired": sum(m.retired for m in ms),
        "rejected": sum(m.rejected for m in ms),
        "steps": sum(m.steps for m in ms),
        "occupancy": round(div(sum(m.slot_steps_live for m in ms),
                               sum(m.slot_steps_total for m in ms)), 3),
        "mean_admission_wait_s": round(div(
            sum(m.admission_wait_s for m in ms),
            sum(m.admitted for m in ms)), 5),
        # speculative ledger (DESIGN.md §19): counters sum, acceptance
        # re-divides from fleet totals like occupancy does
        "drafted_tokens": sum(m.drafted_tokens for m in ms),
        "accepted_tokens": sum(m.accepted_tokens for m in ms),
        "verify_tokens": sum(m.verify_tokens for m in ms),
        "spec_cycles": sum(m.spec_cycles for m in ms),
        "acceptance_rate": round(div(sum(m.accepted_tokens for m in ms),
                                     sum(m.drafted_tokens for m in ms)), 3),
        "ttft_s": Metrics._dist(ttft),
        "tpot_s": Metrics._dist(tpot),
    }


class Router:
    """Load-balancing front door over N ``ServingEngine`` replicas
    (module docstring; semantics in DESIGN.md §17)."""

    def __init__(self, cfg, params, *, config: EngineConfig | None = None,
                 mesh=None, replicas: int | None = None,
                 checkpoint_dir=None):
        """``mesh``: a ('data', 'model') mesh — one replica per data row,
        each tensor-parallel over its own ``model`` sub-axis.  Without a
        mesh, ``replicas`` process-local engines share the host devices
        (useful on one device; the jitted steps are shared, so extra
        replicas cost slots, not compiles).  ``checkpoint_dir`` is the
        default param-handoff directory for drain/restore."""
        self.cfg = cfg
        self.config = config if config is not None else EngineConfig()
        self._params = params
        if mesh is not None:
            groups = mesh_lib.replica_meshes(mesh)
            if replicas is not None and replicas != len(groups):
                raise ValueError(
                    f"replicas={replicas} contradicts the mesh's data "
                    f"axis ({len(groups)} replica groups)")
        else:
            replicas = 1 if replicas is None else replicas
            if replicas < 1:
                raise ValueError(f"replicas must be >= 1, got {replicas}")
            groups = [None] * replicas
        self.replica_meshes = groups
        self.engines: list[ServingEngine | None] = [
            ServingEngine(cfg, params, config=self.config, mesh=g)
            for g in groups]
        self.checkpoint_dir = checkpoint_dir
        self._draining = [False] * len(groups)
        self._ckpt: dict[int, tuple] = {}      # replica -> (dir, step)
        self._ckpt_step = itertools.count()
        self._spill: deque[Handle] = deque()
        self._sessions: dict[str, int] = {}
        self._uids = itertools.count()
        self._handles: dict[int, Handle] = {}
        self._finished: list[Handle] = []
        self._history: list[Metrics] = []      # drained replicas' metrics
        self.spilled = 0
        self.spill_peak = 0
        self.drains = 0
        self.restores = 0

    # ------------------------------------------------------------------
    # Client API: submission
    # ------------------------------------------------------------------

    def submit(self, prompt, sampling: SamplingParams | None = None, *,
               max_new_tokens: int = 16, session: str | None = None,
               uid: int | None = None) -> Handle:
        """Admit one request to the fleet; returns its :class:`Handle`.

        ``prompt`` is a 1-D array of int32 token ids; ``max_new_tokens``
        bounds the generated length (tokens, EOS may stop earlier).
        Oversize requests (prompt + max_new_tokens > max_len) raise
        immediately; everything else is either placed on a replica now or
        parked in the spillover queue until one has room.  Placement is
        least-loaded with ``session`` affinity (DESIGN.md §17).
        """
        prompt = np.asarray(prompt, np.int32)
        if len(prompt) + max_new_tokens > self.config.max_len:
            raise ValueError(
                f"prompt ({len(prompt)}) + max_new_tokens "
                f"({max_new_tokens}) exceeds the fleet max_len "
                f"({self.config.max_len})")
        req = Request(uid=next(self._uids) if uid is None else uid,
                      prompt=prompt, max_new_tokens=max_new_tokens,
                      sampling=sampling)
        req.submit_time = time.perf_counter()   # TTFT from fleet admission
        h = Handle(request=req, session=session)
        self._handles[req.uid] = h
        if not self._try_place(h):
            h.spilled = True
            self._spill.append(h)
            self.spilled += 1
            self.spill_peak = max(self.spill_peak, len(self._spill))
        return h

    def _attached(self):
        return [i for i, e in enumerate(self.engines)
                if e is not None and not self._draining[i]]

    def _has_room(self, i: int) -> bool:
        eng = self.engines[i]
        return eng.max_queue is None or eng.num_pending < eng.max_queue

    def _target_replica(self, h: Handle) -> int | None:
        if h.session is not None and h.session in self._sessions:
            pinned = self._sessions[h.session]
            if self.engines[pinned] is not None \
                    and not self._draining[pinned]:
                # affinity overrides least-loaded; a full pinned queue
                # means the request WAITS for its replica (spillover)
                # rather than landing where its cache slots are not
                return pinned if self._has_room(pinned) else None
            del self._sessions[h.session]       # pin dissolved by drain
        candidates = [i for i in self._attached() if self._has_room(i)]
        if not candidates:
            return None
        return min(candidates, key=lambda i: (
            self.engines[i].num_pending + self.engines[i].num_live, i))

    def _try_place(self, h: Handle) -> bool:
        r = self._target_replica(h)
        if r is None:
            return False
        if not self.engines[r].submit(h.request):
            return False                        # raced a cap; spill
        h.replica = r
        if h.session is not None:
            self._sessions[h.session] = r
        return True

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One fleet tick: re-place spillover FIFO, then tick every
        attached replica once and collect its finishers.  Returns whether
        any work remains or progressed."""
        placed = self._drain_spill()
        progressed = placed
        for i, eng in enumerate(self.engines):
            if eng is None:
                continue
            if eng.step():
                progressed = True
            self._collect_one(eng)
        return progressed or bool(self._spill)

    def _drain_spill(self) -> bool:
        placed = False
        still: deque[Handle] = deque()
        while self._spill:
            h = self._spill.popleft()
            if self._try_place(h):
                placed = True
            else:
                still.append(h)
        self._spill = still
        return placed

    def _collect_one(self, eng: ServingEngine):
        for req in eng.take_finished():
            self._finished.append(self._handles.pop(req.uid))

    def run_to_completion(self) -> list[Handle]:
        """Serve until every queue, slot, and the spillover are empty;
        returns the handles finished since the last call."""
        while True:
            if self._spill and not any(e is not None for e in self.engines):
                raise RuntimeError(
                    "spillover has pending requests but every replica is "
                    "detached — restore() one first")
            if not self.step():
                break
        done, self._finished = self._finished, []
        return done

    # ------------------------------------------------------------------
    # Drain / restore (param handoff via train/checkpoint machinery)
    # ------------------------------------------------------------------

    def drain(self, replica: int, directory=None) -> dict:
        """Gracefully take replica ``replica`` out of the fleet.

        Stops admitting (its session pins dissolve), re-routes its
        queued-but-unadmitted requests through spillover, runs its live
        slots to retirement, checkpoints the serving params for handoff
        (when a directory is configured), and detaches the engine.  The
        replica's Metrics survive in the fleet aggregate as history.
        """
        eng = self.engines[replica]
        if eng is None:
            raise ValueError(f"replica {replica} is already detached")
        self._draining[replica] = True
        for s in [s for s, r in self._sessions.items() if r == replica]:
            del self._sessions[s]
        requeued = eng.take_queued()
        for req in reversed(requeued):          # keep FIFO order at front
            h = self._handles[req.uid]
            h.replica = None
            h.spilled = True
            self._spill.appendleft(h)
        self.spill_peak = max(self.spill_peak, len(self._spill))
        while eng.num_live:                     # let slots retire
            eng.step()
        self._collect_one(eng)
        directory = directory if directory is not None \
            else self.checkpoint_dir
        info = {"replica": replica, "requeued": len(requeued),
                "checkpoint": None}
        if directory is not None:
            step = next(self._ckpt_step)
            state = {"params": self._params}
            extra = {"kind": "serving-params", "replica": replica}
            if getattr(eng, "paged", False):
                # the warm prefix cache survives the drain: live slots
                # just retired, so the page pools hold exactly the prefix
                # index's pages — serialize them (device arrays through
                # the checkpoint tree, bookkeeping through the manifest)
                caches, pool_meta = eng.export_paged_state()
                state["paged_kv"] = caches
                extra["paged_meta"] = pool_meta
            checkpoint.save(directory, state, step=step, extra=extra)
            self._ckpt[replica] = (directory, step)
            info["checkpoint"] = {"directory": str(directory),
                                  "step": step}
        self._history.append(eng.metrics)
        self.engines[replica] = None
        self._draining[replica] = False
        self.drains += 1
        return info

    def restore(self, replica: int, directory=None):
        """Reattach a drained replica: load the handoff checkpoint (or
        fall back to the in-memory params when none was written) and
        rebuild the engine on its original mesh group.  A paged replica
        additionally re-adopts its drained page pools and prefix index
        (manifest ``paged_meta``), so the restored engine's prefix cache
        is as warm as the moment it drained."""
        if self.engines[replica] is not None:
            raise ValueError(f"replica {replica} is attached; drain first")
        if directory is None:
            directory = self._ckpt.get(replica, (self.checkpoint_dir,))[0]
        eng = ServingEngine(self.cfg, self._params, config=self.config,
                            mesh=self.replica_meshes[replica])
        if directory is not None:
            template = {"params": self._params}
            if getattr(eng, "paged", False):
                template["paged_kv"] = eng.caches
            state, manifest = checkpoint.restore(directory, template)
            if getattr(eng, "paged", False) and "paged_meta" in manifest:
                eng.import_paged_state(state["paged_kv"],
                                       manifest["paged_meta"])
        self.engines[replica] = eng
        self.restores += 1
        return self.engines[replica]

    # ------------------------------------------------------------------
    # Reporting
    # ------------------------------------------------------------------

    @property
    def num_pending(self) -> int:
        """Fleet-wide waiting requests (replica queues + spillover)."""
        return len(self._spill) + sum(e.num_pending for e in self.engines
                                      if e is not None)

    def metrics_report(self) -> dict:
        """Fleet report extending the PR 5 engine schema: a ``fleet``
        aggregate (summed tok/s, merged TTFT/TPOT percentiles, spillover
        and drain/restore counters) plus the per-replica reports."""
        live = [e.metrics for e in self.engines if e is not None]
        fleet = {
            "replicas": len(self.engines),
            "attached": sum(e is not None for e in self.engines),
            "spilled": self.spilled,
            "spill_peak": self.spill_peak,
            "spill_pending": len(self._spill),
            "sessions": len(self._sessions),
            "drains": self.drains,
            "restores": self.restores,
            **aggregate_reports(live + self._history),
        }
        return {
            "fleet": fleet,
            "replica_reports": [None if e is None else e.metrics.report()
                                for e in self.engines],
        }

    def capacity_report(self) -> dict:
        """Fleet capacity: per-replica slots summed, shard plans named."""
        per = [None if e is None else e.capacity_report()
               for e in self.engines]
        return {
            "replicas": len(self.engines),
            "fleet_slots": sum(p["slots"] for p in per if p is not None),
            "replica_capacity": per,
        }

    def reset_metrics(self):
        """Zero every replica's counters and the router's own (benchmark
        warmup support — mirrors ``eng.metrics = Metrics()``)."""
        for e in self.engines:
            if e is not None:
                e.metrics = Metrics()
        self._history = []
        self.spilled = self.spill_peak = 0
        self.drains = self.restores = 0

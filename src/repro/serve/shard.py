"""Serving ShardPlan: explicit tensor-parallel layout for packed inference.

The serving stack was implicitly single-device: ``prepare_serving_params``
packed weights on whatever device jax defaulted to, ``ServingEngine`` jitted
its steps against unsharded trees, and "how is this tensor laid out across
devices" lived nowhere.  A :class:`ShardPlan` makes that an explicit object
(DESIGN.md §15): given a serving mesh it computes NamedShardings for every
leaf of the *packed* serving tree and for the (possibly sub-byte packed)
decode caches, and the engine places both before jitting.

Layout scheme — chosen so sub-byte packing stays exact under sharding:

* **Packed weights shard the output (N) axis** over the TP axis
  ('model'), i.e. every packed Dense is column-parallel.  Lane packing
  (P1) and bit-dense word packing both run along the *contraction* (K)
  axis, which this scheme keeps replicated — so an int32 word or int16
  lane never straddles a shard boundary and each device holds whole,
  locally-decodable words ("packing along the replicated axis",
  ISSUE 5).  Row-parallel K-sharding would make XLA psum *packed* s32
  totals across shards before shift-mask extraction — summing more than
  ``k_tile`` lanes' worth of D-band contributions, which overflows the
  field and silently corrupts the dot (core/packing.k_tile_bound).
* ``col_sums`` / ``bias`` ([N]) shard with their columns; quant scalars
  (``w_scale``/``a_scale``/``w_zp``/``a_zp``) replicate.
* **Unpacked leaves replicate** (embedding tables, norms, fake-quant MoE
  experts): serving batches are small, replication keeps the gather /
  einsum paths collective-free.  The sharded-vocab embedding lookup in
  models/common still engages under the active mesh (shard_map + psum of
  masked gathers — exact, each row is one shard's value plus zeros).
* **KV caches shard the kv-head axis** (axis 2 of [B, S, KVH, hd|words]
  and of the [B, S, KVH] scale planes) over 'model' — quantization,
  word-packing, ring writes and fused-dequant reads are all per-(pos,
  kv-head) local, so a head shard never touches another shard's words
  (parallel/sharding.cache_shardings(kv_head_shard=True)).  Recurrent
  states (mamba conv/ssm, xLSTM C/n/m) shard their channel dims via the
  same rules.

Every rule is divisibility-guarded: an axis that does not divide the dim is
dropped (replicated), so a mesh with model=1 — or a tensor that cannot
shard — degrades to exactly the single-device layout and the engine is
behaviorally unchanged.
"""

from __future__ import annotations

import dataclasses
import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel import sharding as sharding_lib

#: Packed-Dense leaf names whose trailing axis is the output (N) axis.
_COLUMN_LEAVES = re.compile(r"/(w_packed|w_dense|kernel)$")
_VECTOR_LEAVES = re.compile(r"/(col_sums|bias)$")
_SCALAR_LEAVES = re.compile(r"/(w_scale|a_scale|w_zp|a_zp|k_full|w_step|"
                            r"a_step)$")


@dataclasses.dataclass(frozen=True)
class ShardPlan:
    """Frozen description of how one serving deployment lays tensors out.

    ``axis`` is the tensor-parallel mesh axis name.  The plan is pure
    metadata — building one never touches device state; placement happens
    in :meth:`place_params` / :meth:`place_caches` (device_put with the
    computed NamedShardings), which the engine calls once at init.
    """

    mesh: Mesh
    axis: str = "model"

    @property
    def model_shards(self) -> int:
        return int(self.mesh.shape.get(self.axis, 1))

    def shards_of(self, n: int) -> int:
        """How many ways dim ``n`` actually shards (1 when indivisible)."""
        s = self.model_shards
        return s if s > 0 and n % s == 0 else 1

    def local_out(self, n: int) -> int:
        """Per-shard local size of an output dim planned at global ``n``.

        This is the shape serve/prepare.build_layer_plans plans against:
        KernelPlan signatures — and therefore the PR 4 autotune cache keys
        — describe what one shard executes, not the global matmul.
        """
        return n // self.shards_of(n)

    # ------------------------------------------------------------------
    # Param shardings (packed serving tree)
    # ------------------------------------------------------------------

    def param_pspec(self, path: str, leaf) -> P:
        shape = np.shape(leaf)
        if not shape or _SCALAR_LEAVES.search(path):
            return P()
        if _VECTOR_LEAVES.search(path) and len(shape) == 1:
            return self._guard(shape, P(self.axis))
        if _COLUMN_LEAVES.search(path) and len(shape) == 2:
            # [Kp|Kw|K, N]: shard columns; K (where the packed words /
            # lanes live) stays replicated => word boundaries shard-local
            return self._guard(shape, P(None, self.axis))
        return P(*([None] * len(shape)))       # replicate everything else

    def param_shardings(self, params):
        def one(path, leaf):
            ps = sharding_lib.path_str(path)
            return NamedSharding(self.mesh, self.param_pspec(f"/{ps}", leaf))
        return jax.tree_util.tree_map_with_path(one, params)

    def place_params(self, params):
        """device_put the packed tree onto the mesh per the plan."""
        return jax.device_put(params, self.param_shardings(params))

    # ------------------------------------------------------------------
    # Cache shardings (kv-head axis; quantized layouts included)
    # ------------------------------------------------------------------

    def cache_shardings(self, caches, cfg, batch: int, *,
                        paged: bool = False):
        """``paged=True``: the attention leaves are page pools
        [P, page_size, KVH, ...] — kv-head rule unchanged (KVH is still
        axis 2), but the page axis replicates: pages are shared physical
        capacity any slot's block table may point into (DESIGN.md §18)."""
        return sharding_lib.cache_shardings(
            caches, cfg, self.mesh, batch, kv_head_shard=True, paged=paged)

    def place_caches(self, caches, cfg, batch: int, *, paged: bool = False):
        shardings = self.cache_shardings(caches, cfg, batch, paged=paged)
        return jax.tree.map(
            lambda c, s: None if c is None else jax.device_put(c, s),
            caches, shardings, is_leaf=lambda x: x is None)

    # ------------------------------------------------------------------

    def _guard(self, shape, spec: P) -> P:
        return sharding_lib._guard(self.mesh, shape, spec)

    def describe(self) -> dict:
        """Flat report row (serve CLI / microbench)."""
        return {"mesh": dict(self.mesh.shape), "tp_axis": self.axis,
                "model_shards": self.model_shards}

"""Batched serving engine: continuous-batching scheduler over prefill/decode.

Production shape: requests arrive with prompts; the engine packs up to
``max_batch`` concurrent sequences, prefills each prompt into its batch slot,
then decodes all live slots in lockstep, retiring finished sequences and
admitting queued ones into freed slots (continuous batching).  All steps are
jitted once per (batch, cache) shape.

The decode path runs the paper's packed integer kernels via
prepare.prepare_serving_params (quant_mode='packed').
"""

from __future__ import annotations

import dataclasses
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import lm
from repro.serve.prepare import build_layer_plans, prepare_serving_params


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, cfg, params, *, max_batch: int = 4,
                 max_len: int = 512, packed: bool = True, greedy=True,
                 dense_store: bool = False):
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.greedy = greedy
        self.params = prepare_serving_params(params, cfg,
                                             dense_store=dense_store) \
            if packed else params
        # Kernel plans are fixed at engine init (paper §IV: one execution
        # plan per layer, chosen offline) — decode-time dispatch hits these
        # memoized objects instead of re-deciding per call.
        self.plans = build_layer_plans(self.params, cfg,
                                       batch_rows=max_batch) if packed else {}
        self._decode = jax.jit(steps_lib.make_decode_step(cfg))
        self._queue: deque[Request] = deque()
        self.caches = lm.init_caches(cfg, max_batch, max_len,
                                     dtype=jnp.bfloat16)
        # per-slot bookkeeping
        self.slot_req: list = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)

    def submit(self, req: Request):
        self._queue.append(req)

    def _admit(self):
        """Fill free slots; per-slot prefill via sequential decode of the
        prompt (slot-addressed caches keep this simple and allocation-free)."""
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None and self._queue:
                req = self._queue.popleft()
                self.slot_req[slot] = req
                self.slot_pos[slot] = 0
                # feed prompt tokens one at a time into this slot
                for tok in req.prompt:
                    self._step_slot(slot, int(tok))

    def _step_slot(self, slot, token):
        """Advance one slot by one token (used for prompt feeding)."""
        tokens = np.zeros((self.max_batch, 1), np.int32)
        tokens[slot, 0] = token
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.mrope:
            p = np.tile(self.slot_pos[:, None], (1, 1))
            batch["positions3"] = jnp.asarray(
                np.broadcast_to(p[None], (3, self.max_batch, 1)))
        logits, self.caches = self._decode(
            self.params, self.caches, batch,
            jnp.int32(int(self.slot_pos[slot])))
        self.slot_pos[slot] += 1
        return np.asarray(logits[slot])

    def step(self):
        """One lockstep decode over all live slots."""
        self._admit()
        live = [s for s in range(self.max_batch)
                if self.slot_req[s] is not None]
        if not live:
            return False
        tokens = np.zeros((self.max_batch, 1), np.int32)
        for s in live:
            req = self.slot_req[s]
            last = req.output[-1] if req.output else int(req.prompt[-1])
            tokens[s, 0] = last
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.mrope:
            p = self.slot_pos[:, None]
            batch["positions3"] = jnp.asarray(
                np.broadcast_to(p[None], (3, self.max_batch, 1)).copy())
        # lockstep: all slots share a position index per jit signature; use
        # per-slot positions via the max (ring caches tolerate gaps)
        idx = int(max(self.slot_pos[s] for s in live))
        logits, self.caches = self._decode(self.params, self.caches, batch,
                                           jnp.int32(idx))
        logits = np.asarray(logits)
        for s in live:
            req = self.slot_req[s]
            nxt = int(np.argmax(logits[s]))
            req.output.append(nxt)
            self.slot_pos[s] += 1
            if len(req.output) >= req.max_new_tokens:
                req.done = True
                self.slot_req[s] = None
        return True

    def plan_report(self):
        """Flat per-layer plan rows (path + KernelPlan.describe())."""
        return [{"layer": path, **plan.describe()}
                for path, plan in sorted(self.plans.items())]

    def run_to_completion(self):
        done = []
        while self._queue or any(r is not None for r in self.slot_req):
            before = [r for r in self.slot_req if r is not None]
            self.step()
            done.extend(r for r in before if r.done)
        return done

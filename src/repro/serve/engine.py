"""Continuous-batching serving engine: chunked prefill + ragged decode.

Scheduler shape (DESIGN.md §12 "Serving scheduler"): requests wait in a
bounded queue (backpressure), an admission pass moves them into free batch
slots, prompts stream through the jitted chunked-prefill step — [B, chunk]
token windows per slot, so admission costs O(prompt_len / chunk) launches
at batched arithmetic intensity instead of O(prompt_len) batch-1 decode
steps — and live slots decode lockstep-free: every slot carries its own
position, cache writes land at per-slot offsets (``cache_valid`` /
vector ``cache_index`` in models/lm.forward), and sampling (greedy /
temperature / top-k) is per slot.  Decode-phase slots ride along inside
prefill passes with their single pending token, finished sequences retire
immediately, and freed slots are re-admitted the same step.

Both steps run the paper's packed integer kernels via
prepare.prepare_serving_params (quant_mode='packed'); KernelPlans for the
decode and prefill row counts are fixed at engine init (paper §IV: one
execution plan per layer, chosen offline).

With ``EngineConfig(paged=True)`` the slot-contiguous KV cache becomes a
refcounted page pool behind per-slot block tables (serve/pages.py,
DESIGN.md §18): admission reserves pages instead of max_len slots, prompt
prefixes are shared via a radix index with copy-on-write on divergence,
and retirement frees pages — the HBM budget then bounds *physical* pages
while ``max_batch`` bounds *logical* slots.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import deque

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch import steps as steps_lib
from repro.models import lm
from repro.serve import pages as pages_lib
from repro.serve import speculative as speculative_lib
from repro.serve.config import EngineConfig, SamplingParams
from repro.serve.prepare import (build_layer_plans, cache_bytes_per_slot,
                                 cache_page_bytes, prepare_serving_params)

__all__ = ["EngineConfig", "Metrics", "Request", "SamplingParams",
           "ServingEngine"]


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray          # [S] int32
    max_new_tokens: int = 16
    sampling: SamplingParams | None = None   # engine default when None
    output: list = dataclasses.field(default_factory=list)
    done: bool = False
    submit_time: float = 0.0
    admit_time: float = 0.0
    first_token_time: float = 0.0
    finish_time: float = 0.0


@dataclasses.dataclass
class Metrics:
    """Engine-level counters (DESIGN.md §12): throughput split by phase,
    admission latency, slot occupancy, backpressure rejections.

    ``prefill_tokens`` counts prompt tokens consumed by chunked prefill;
    ``generated_tokens`` counts every sampled token; ``decode_tokens``
    only those sampled in pure decode passes, so decode_tok_s divides
    tokens by the wall time of the same passes.  Tokens sampled inside a
    mixed prefill pass (decode riders, first token after a prompt
    completes) count as generated but land in the prefill time bucket.

    Per-request latency: ``ttft_s`` records one time-to-first-token sample
    per request (submit -> first sampled token, so queue wait counts —
    the number a client sees); ``tpot_s`` one time-per-output-token sample
    per *retired* request with >= 2 output tokens (first token -> finish,
    per subsequent token).  ``report()`` surfaces mean / p50 / p95 of
    both (DESIGN.md §12).

    Speculative decoding (DESIGN.md §19) adds the draft/verify ledger:
    ``drafted_tokens`` counts draft proposals actually considered
    (per-slot ``limit``, not k x cycles), ``accepted_tokens`` those the
    rejection rule kept, ``verify_tokens`` target window rows scored,
    and ``spec_cycles`` draft+verify launch pairs.  ``report()`` derives
    ``acceptance_rate`` = accepted / drafted — the knob that decides
    whether k was too ambitious for the draft's fidelity.  Committed
    tokens still land in ``decode_tokens``, so ``decode_tok_s`` stays
    directly comparable with a non-speculative engine.
    """
    prefill_tokens: int = 0
    generated_tokens: int = 0
    decode_tokens: int = 0
    prefill_time_s: float = 0.0
    decode_time_s: float = 0.0
    admitted: int = 0
    retired: int = 0
    rejected: int = 0
    steps: int = 0
    slot_steps_live: int = 0
    slot_steps_total: int = 0
    admission_wait_s: float = 0.0
    drafted_tokens: int = 0
    accepted_tokens: int = 0
    verify_tokens: int = 0
    spec_cycles: int = 0
    ttft_s: list = dataclasses.field(default_factory=list)
    tpot_s: list = dataclasses.field(default_factory=list)

    @staticmethod
    def _dist(samples) -> dict:
        if not samples:
            return {"mean": 0.0, "p50": 0.0, "p95": 0.0}
        arr = np.asarray(samples, np.float64)
        return {"mean": round(float(arr.mean()), 5),
                "p50": round(float(np.percentile(arr, 50)), 5),
                "p95": round(float(np.percentile(arr, 95)), 5)}

    def report(self) -> dict:
        def div(a, b):
            return a / b if b else 0.0
        return {
            "prefill_tokens": self.prefill_tokens,
            "generated_tokens": self.generated_tokens,
            "decode_tokens": self.decode_tokens,
            "prefill_tok_s": round(div(self.prefill_tokens,
                                       self.prefill_time_s), 1),
            "decode_tok_s": round(div(self.decode_tokens,
                                      self.decode_time_s), 1),
            "admitted": self.admitted,
            "retired": self.retired,
            "rejected": self.rejected,
            "steps": self.steps,
            "occupancy": round(div(self.slot_steps_live,
                                   self.slot_steps_total), 3),
            "mean_admission_wait_s": round(div(self.admission_wait_s,
                                               self.admitted), 5),
            "drafted_tokens": self.drafted_tokens,
            "accepted_tokens": self.accepted_tokens,
            "verify_tokens": self.verify_tokens,
            "spec_cycles": self.spec_cycles,
            "acceptance_rate": round(div(self.accepted_tokens,
                                         self.drafted_tokens), 3),
            "ttft_s": self._dist(self.ttft_s),
            "tpot_s": self._dist(self.tpot_s),
        }


class ServingEngine:
    """Admission scheduler over chunked prefill + ragged decode (module
    docstring; scheduler design in DESIGN.md §12)."""

    def __init__(self, cfg, params, *, config: EngineConfig | None = None,
                 mesh=None, **legacy):
        # One constructor path (DESIGN.md §17): a frozen, pre-validated
        # EngineConfig.  ``mesh`` stays a direct argument because it is a
        # live placement object (devices), not serializable configuration.
        # The PR 7 deprecation shim for the old 12-keyword surface served
        # its one-release grace period and is gone.
        if legacy:
            raise TypeError(
                f"ServingEngine no longer accepts engine keywords (got "
                f"{sorted(legacy)}); pass config=EngineConfig(...) from "
                f"repro.serve.config instead")
        config = config if config is not None else EngineConfig()
        self.config = config
        packed = config.packed
        self.cfg = cfg
        # Mesh-native serving (DESIGN.md §15): with a mesh, a ShardPlan
        # makes the cross-device layout explicit — packed weights
        # column-parallel (word boundaries shard-local), caches sharded on
        # the kv-head axis — and params/caches are placed before the steps
        # are jitted, so GSPMD partitions both jitted steps against
        # committed shardings.  mesh=None (or model axis 1) degrades to
        # the single-device layout: every spec guards to replicated.
        self.mesh = mesh
        self.shard_plan = None
        self._tp_axis = None
        if mesh is not None:
            from repro.serve.shard import ShardPlan
            self.shard_plan = ShardPlan(mesh)
            if self.shard_plan.model_shards > 1:
                self._tp_axis = self.shard_plan.axis
        # Slot capacity is cache-bytes-aware: with an explicit HBM cache
        # budget the engine admits budget // bytes-per-slot concurrent
        # sequences, so quantized caches (cfg.quant.kv_bits in {8, 4, 2})
        # convert their density directly into batch slots — the capacity
        # rule itself lives in EngineConfig.slots_for (DESIGN.md §13).
        # Paged mode (DESIGN.md §18) changes the capacity unit: the budget
        # buys a pool of pages (EngineConfig.pages_for), logical slots are
        # bounded only by max_batch, and each admission reserves just the
        # pages its request can actually write — shared prompt prefixes
        # and short sequences stop stranding whole max_len slots.
        kv_bits = getattr(cfg.quant, "kv_bits", 0)
        self.paged = config.paged
        self.page_size = config.page_size
        self.cache_bytes_per_slot = cache_bytes_per_slot(cfg, config.max_len)
        self.hbm_cache_budget = config.hbm_cache_budget
        if self.paged:
            if cfg.sliding_window:
                raise ValueError(
                    "paged KV cache and the sliding-window ring layout do "
                    "not compose (attention rejects block_tables there); "
                    "use paged=False for sliding-window configs")
            pages_lib.validate_page_size(self.page_size, kv_bits)
            self.page_bytes = cache_page_bytes(cfg, self.page_size)
            if self.page_bytes == 0:
                raise ValueError(
                    "paged=True requires at least one attention layer "
                    "(nothing pageable in an attention-free stack)")
            self.pages_per_slot = -(-config.max_len // self.page_size)
            self.num_pages = config.pages_for(self.page_bytes,
                                              self.pages_per_slot)
            # admission-time estimate: what one worst-case (no-sharing,
            # full-extent) request would pin
            self.cache_bytes_per_slot = self.pages_per_slot * self.page_bytes
            max_batch = config.max_batch
            # prefix skip is only token-exact when every layer's state is
            # reconstructible from the shared pages — i.e. a pure-attention
            # decoder stack (recurrent layers carry unpaged per-slot state;
            # cross-attention caches key off encoder output, not prompt
            # ids).  Paging without sharing still works for those.
            self._share = (config.prefix_sharing
                           and not cfg.is_encoder_decoder
                           and all(cfg.layer_kind(i) == "attn"
                                   for i in range(cfg.num_layers)))
        else:
            max_batch = config.slots_for(self.cache_bytes_per_slot)
        self.max_batch = max_batch
        self.max_len = config.max_len
        self.prefill_chunk = config.prefill_chunk
        if cfg.sliding_window:
            # ring caches admit only token-by-token prefill: a >1-token
            # window would overwrite ring slots still visible to earlier
            # queries of the same window (attention rejects that case)
            self.prefill_chunk = 1
        self.max_queue = config.max_queue
        self.sampling = config.sampling
        self.params = prepare_serving_params(
            params, cfg, dense_store=config.dense_store) \
            if packed else params
        # Kernel plans are fixed at engine init (paper §IV: one execution
        # plan per layer, chosen offline) for both jitted row counts —
        # decode (max_batch rows) and chunked prefill (max_batch * chunk);
        # under a shard plan they are built against per-shard local output
        # widths, what one device actually executes.
        # ``autotune=True`` warm-tunes missing signatures first (the
        # tune-once-offline deployment pass, DESIGN.md §14).
        self.plans = build_layer_plans(
            self.params, cfg, batch_rows=max_batch,
            prefill_rows=max_batch * self.prefill_chunk,
            autotune=config.autotune,
            shard_plan=self.shard_plan) if packed else {}
        if self.shard_plan is not None:
            self.params = self.shard_plan.place_params(self.params)
        # Jitted steps are memoized per (cfg, tp axis, mesh devices): a
        # replica fleet (serve/router.Router) over one model shares a
        # single trace/compile across layout-identical replicas instead of
        # paying it N times.
        self._decode, self._prefill = steps_lib.jitted_serving_steps(
            cfg, kv_shard_axis=self._tp_axis, mesh=self.mesh)
        self._queue: deque[Request] = deque()
        if self.paged:
            self.caches = lm.init_caches(cfg, max_batch, self.max_len,
                                         dtype=jnp.bfloat16,
                                         page_size=self.page_size,
                                         num_pages=self.num_pages)
            self.pool = pages_lib.PagePool(self.num_pages, self.page_size,
                                           kv_bits)
            self.block_tables = np.zeros((max_batch, self.pages_per_slot),
                                         np.int32)
            self._slot_extent = [0] * max_batch   # table entries in use
            self._slot_spare: list = [[] for _ in range(max_batch)]
            self.peak_live_slots = 0
        else:
            self.caches = lm.init_caches(cfg, max_batch, self.max_len,
                                         dtype=jnp.bfloat16)
        if self.shard_plan is not None:
            self.caches = self.shard_plan.place_caches(
                self.caches, cfg, max_batch, paged=self.paged)
        # batch-1 fresh-cache template: admission resets a slot's rows from
        # it (recurrent states have non-zero init, e.g. mLSTM m = -inf)
        self._fresh = lm.init_caches(cfg, 1, self.max_len,
                                     dtype=jnp.bfloat16)
        # Speculative decoding (DESIGN.md §19): a DraftModel re-packs the
        # SAME checkpoint at draft_w_bits with its own caches (and, paged,
        # its own small page pool), and pure-decode passes become
        # draft-k + verify-in-one-call cycles (_speculative_pass).
        self.spec = None
        self._verify = None
        if config.speculative_k:
            self._validate_speculative(cfg)
            self.spec = speculative_lib.DraftModel(
                cfg, params, config, max_batch=max_batch,
                max_len=self.max_len, shard_plan=self.shard_plan,
                mesh=self.mesh, tp_axis=self._tp_axis)
            _, self._verify = steps_lib.jitted_speculative_steps(
                cfg, self.spec.cfg, config.speculative_k,
                kv_shard_axis=self._tp_axis, mesh=self.mesh)
        # per-slot bookkeeping
        self.slot_req: list = [None] * max_batch
        self.slot_pos = np.zeros(max_batch, np.int32)   # tokens in cache
        self.slot_fed = np.zeros(max_batch, np.int32)   # prompt consumed
        self._slot_rng: list = [None] * max_batch
        self._finished: list = []
        self.metrics = Metrics()

    @staticmethod
    def _validate_speculative(cfg):
        """Speculation needs a pure-attention decoder whose chunked
        writes equal sequential writes — the verify-window rollback
        argument (DESIGN.md §19) does not hold for ring caches,
        recurrent state, or position schemes the draft step does not
        model."""
        problems = []
        if cfg.is_encoder_decoder:
            problems.append("encoder-decoder stacks")
        if cfg.sliding_window:
            problems.append("sliding-window (ring) KV caches")
        if cfg.mrope:
            problems.append("M-RoPE position ids")
        if any(cfg.layer_kind(i) != "attn" for i in range(cfg.num_layers)):
            problems.append("non-attention (recurrent) layers")
        if problems:
            raise ValueError(
                f"speculative_k > 0 requires a pure-attention decoder "
                f"stack; this config has: {', '.join(problems)}")

    def _mesh_ctx(self):
        """Announce the serving mesh to sharding.constrain() for the
        duration of a jitted-step call — constrain() and the sharded-vocab
        embedding path read the active mesh at trace time, so the first
        call under this context bakes the mesh into both executables."""
        if self.mesh is None:
            return contextlib.nullcontext()
        from repro.parallel.sharding import activation_mesh
        return activation_mesh(self.mesh)

    # ------------------------------------------------------------------
    # Submission / admission
    # ------------------------------------------------------------------

    def submit(self, req: Request) -> bool:
        """Queue a request.  Returns False (rejected, counted in metrics)
        when the backpressure cap ``max_queue`` is hit."""
        if len(req.prompt) + req.max_new_tokens > self.max_len:
            raise ValueError(
                f"request {req.uid}: prompt ({len(req.prompt)}) + "
                f"max_new_tokens ({req.max_new_tokens}) exceeds engine "
                f"max_len ({self.max_len})")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.metrics.rejected += 1
            return False
        if not req.submit_time:
            # the fleet Router stamps submit_time at fleet admission so a
            # spilled request's TTFT includes its spillover wait
            req.submit_time = time.perf_counter()
        self._queue.append(req)
        return True

    def _reset_slot(self, slot: int):
        """Restore one batch row of the recurrent-state cache leaves to
        their freshly-initialized values (mamba conv/ssm, xLSTM C/n/m —
        non-zero inits included).  Attention rows need no reset: their
        validity is re-derived per call from cache_index/cache_valid, so
        stale entries are masked until overwritten."""

        def reset(cur, fresh):
            return cur.at[slot:slot + 1].set(fresh.astype(cur.dtype))

        out = []
        for cur_layer, fresh_layer in zip(self.caches, self._fresh):
            layer = dict(cur_layer)
            for kind, sub in cur_layer.items():
                if kind == "attn" or sub is None:
                    continue
                layer[kind] = jax.tree.map(reset, sub, fresh_layer[kind])
            out.append(layer)
        self.caches = out

    # -- paged reservation / copy-on-write -----------------------------

    def _reserve_pages(self, slot: int, req: Request) -> int | None:
        """Reserve every page ``req`` can write, all-or-nothing.

        Positions written span ``[0, W)`` with ``W = len(prompt) +
        max_new_tokens - 1`` (the last sampled token is returned, never
        cached).  A cached prefix match (capped at ``len(prompt) - 1``,
        match_prefix docstring) contributes shared pages — retained, not
        copied; fresh pages cover the rest, plus COW spares for the two
        divergence writes a request can hit: its first write into a
        partially-shared page, and its first generated token landing in
        the prompt's registered tail page.  Returns the shared token
        count, or None (nothing taken) when the pool cannot cover it —
        the request stays queued, FIFO preserved.
        """
        ps = self.page_size
        n_prompt = len(req.prompt)
        written = n_prompt + req.max_new_tokens - 1
        n_shared, shared = 0, []
        if self._share:
            n_shared, shared = self.pool.match_prefix(
                req.prompt, max_tokens=n_prompt - 1)
        first_partial = 1 if n_shared % ps else 0
        fill_from = n_shared // ps + first_partial
        fresh = -(-written // ps) - fill_from
        tail_cow = 1 if (self._share and n_prompt % ps
                         and written > n_prompt) else 0
        for pg, _rows in shared:             # pin before alloc can evict
            self.pool.retain(pg)
        got = self.pool.alloc(fresh + first_partial + tail_cow)
        if got is None:
            for pg, _rows in shared:
                self.pool.release(pg)
            return None
        table = self.block_tables[slot]
        table[:] = 0
        for i, (pg, _rows) in enumerate(shared):
            table[i] = pg
        table[fill_from:fill_from + fresh] = got[:fresh]
        self._slot_extent[slot] = fill_from + fresh
        self._slot_spare[slot] = got[fresh:]
        if n_shared:
            self.pool.prefix_hits += 1
            self.pool.prefix_hit_tokens += n_shared
        return n_shared

    def _release_slot_pages(self, slot: int):
        for p in self.block_tables[slot][:self._slot_extent[slot]]:
            self.pool.release(int(p))
        for p in self._slot_spare[slot]:
            self.pool.release(int(p))
        self.block_tables[slot][:] = 0
        self._slot_extent[slot] = 0
        self._slot_spare[slot] = []

    def _ensure_writable(self, slot: int, lo: int, hi: int):
        """Copy-on-write ahead of a pass writing positions ``[lo, hi)``:
        any mapped page that is shared (ref > 1) or frozen by the prefix
        index gets a private copy first (reserved spare, else a fresh
        alloc under pressure), so writers never touch shared bytes."""
        ps = self.page_size
        table = self.block_tables[slot]
        for pi in range(lo // ps, -(-hi // ps)):
            pg = int(table[pi])
            if not (self.pool.is_shared(pg) or self.pool.is_immutable(pg)):
                continue
            spare = self._slot_spare[slot]
            if spare:
                dst = spare.pop()
            else:
                got = self.pool.alloc(1)
                if got is None:
                    raise RuntimeError(
                        f"page pool exhausted during copy-on-write for "
                        f"slot {slot} (page {pg}); reservation math must "
                        f"cover every divergence write")
                dst = got[0]
            self.caches = pages_lib.copy_page(self.caches, pg, dst)
            table[pi] = dst
            self.pool.release(pg)
            self.pool.cow_copies += 1

    def _admit(self):
        now = time.perf_counter()
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None and self._queue:
                req = self._queue[0]
                n_shared = 0
                if self.paged:
                    reserved = self._reserve_pages(slot, req)
                    if reserved is None:
                        # head-of-line blocks until pages free: FIFO, no
                        # starvation of large requests by small ones
                        break
                    n_shared = reserved
                self._queue.popleft()
                self._reset_slot(slot)
                self.slot_req[slot] = req
                self.slot_pos[slot] = n_shared
                self.slot_fed[slot] = n_shared
                if self.spec is not None:
                    # the draft replays the FULL prompt (no prefix skip:
                    # its cache has no rows for skipped positions)
                    self.spec.begin_slot(slot, req)
                sp = req.sampling or self.sampling
                self._slot_rng[slot] = np.random.default_rng(
                    (sp.seed, req.uid & 0xFFFFFFFF))
                req.admit_time = now
                self.metrics.admitted += 1
                self.metrics.admission_wait_s += now - req.submit_time

    # ------------------------------------------------------------------
    # Stepping
    # ------------------------------------------------------------------

    def step(self) -> bool:
        """One scheduler tick: admit, then one batched model pass —
        chunked prefill while any slot is mid-prompt (decode-phase slots
        ride along), else a single-token ragged decode."""
        self._admit()
        live = [s for s in range(self.max_batch)
                if self.slot_req[s] is not None]
        if not live:
            return False
        self.metrics.steps += 1
        self.metrics.slot_steps_live += len(live)
        self.metrics.slot_steps_total += self.max_batch
        if self.paged:
            self.peak_live_slots = max(self.peak_live_slots, len(live))
        prefilling = any(
            self.slot_fed[s] < len(self.slot_req[s].prompt) for s in live)
        if self.spec is not None:
            # the draft may still be replaying a prefix-skipped prompt
            # after the target finished; keep the pass a prefill pass
            # (speculation only runs on pure-decode passes)
            prefilling = prefilling or any(
                not self.spec.prompt_done(s, self.slot_req[s])
                for s in live)
        t0 = time.perf_counter()
        if prefilling:
            n_prompt = self._prefill_pass(live)
            self.metrics.prefill_time_s += time.perf_counter() - t0
            self.metrics.prefill_tokens += n_prompt
        elif self.spec is not None:
            self._speculative_pass(live)
            self.metrics.decode_time_s += time.perf_counter() - t0
        else:
            self._decode_pass(live)
            self.metrics.decode_time_s += time.perf_counter() - t0
        return True

    def _positions3(self, index: np.ndarray, width: int):
        pos = index[:, None] + np.arange(width, dtype=np.int32)[None, :]
        return jnp.asarray(
            np.broadcast_to(pos[None], (3, self.max_batch, width)).copy())

    def _prefill_pass(self, live) -> int:
        c = self.prefill_chunk
        tokens = np.zeros((self.max_batch, c), np.int32)
        index = np.zeros(self.max_batch, np.int32)
        valid = np.zeros(self.max_batch, np.int32)
        take = {}
        n_prompt = 0
        for s in live:
            req = self.slot_req[s]
            index[s] = self.slot_pos[s]
            rem = len(req.prompt) - int(self.slot_fed[s])
            if rem > 0:        # mid-prompt: its next chunk window
                t = min(c, rem)
                fed = int(self.slot_fed[s])
                tokens[s, :t] = req.prompt[fed:fed + t]
                valid[s] = take[s] = t
                n_prompt += t
            elif req.output:   # decode-phase rider: one pending token
                tokens[s, 0] = req.output[-1]
                valid[s] = 1
            # else: target prompt done but the first token is stashed
            # until the speculative draft finishes its full-prompt
            # replay — a dead slot (valid 0) in this target pass
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.mrope:
            batch["positions3"] = self._positions3(index, c)
        step_args = ()
        if self.paged:
            for s in live:
                lo = int(index[s])
                self._ensure_writable(s, lo, lo + int(valid[s]))
            step_args = (jnp.asarray(self.block_tables),)
        logits = None
        if int(valid.sum()):   # all-stash-waiting passes skip the launch
            with self._mesh_ctx():
                logits, self.caches = self._prefill(
                    self.params, self.caches, batch, jnp.asarray(index),
                    jnp.asarray(valid), *step_args)
            logits = np.asarray(logits)
        if self.spec is not None:
            self._draft_prefill(live)
        for s in live:
            req = self.slot_req[s]
            if s in take:
                self.slot_fed[s] += take[s]
                self.slot_pos[s] += take[s]
                if self.slot_fed[s] == len(req.prompt):
                    if self.paged and self._share:
                        self._register_prompt(s, req)
                    if self.spec is None or self.spec.prompt_done(s, req):
                        self._emit_token(s, logits[s],
                                         decode_pass=False)  # first token
                    else:
                        # prefix sharing let the target finish before the
                        # draft's full replay: park the first-token logits
                        self.spec.stash(s, logits[s])
            elif req.output:
                self.slot_pos[s] += 1
                self._emit_token(s, logits[s], decode_pass=False)
            elif self.spec is not None and self.spec.has_stash(s) \
                    and self.spec.prompt_done(s, req):
                # the draft just caught up: emit the parked first token
                self._emit_token(s, self.spec.pop_stash(s),
                                 decode_pass=False)
        return n_prompt

    def _draft_prefill(self, live):
        """Feed the speculative draft cache its own prefill window:
        prompt chunks for slots still replaying (from draft position
        ``fed`` — the draft never prefix-skips, DESIGN.md §19), the
        single pending token for decode riders so draft and target
        caches stay position-aligned through mixed passes."""
        spec = self.spec
        c = self.prefill_chunk
        tokens = np.zeros((self.max_batch, c), np.int32)
        index = np.zeros(self.max_batch, np.int32)
        valid = np.zeros(self.max_batch, np.int32)
        fed_take = {}
        for s in live:
            req = self.slot_req[s]
            fed = int(spec.fed[s])
            rem = len(req.prompt) - fed
            if rem > 0:
                t = min(c, rem)
                tokens[s, :t] = req.prompt[fed:fed + t]
                index[s] = fed
                valid[s] = fed_take[s] = t
            elif req.output:
                tokens[s, 0] = req.output[-1]
                index[s] = self.slot_pos[s]
                valid[s] = 1
        if not int(valid.sum()):
            return
        step_args = (jnp.asarray(spec.block_tables),) if spec.paged else ()
        with self._mesh_ctx():
            _, spec.caches = spec._prefill(
                spec.params, spec.caches, {"tokens": jnp.asarray(tokens)},
                jnp.asarray(index), jnp.asarray(valid), *step_args)
        for s, t in fed_take.items():
            spec.fed[s] += t

    def _register_prompt(self, s: int, req: Request):
        """Hash-cons the just-completed prompt's pages into the prefix
        index (before the first generated token, which may retire the
        slot immediately at max_new_tokens=1): later requests with the
        same prefix share these physical pages instead of re-prefilling."""
        n_pages = -(-len(req.prompt) // self.page_size)
        self.pool.register_prefix(
            req.prompt, [int(p) for p in self.block_tables[s][:n_pages]])

    def _decode_pass(self, live):
        tokens = np.zeros((self.max_batch, 1), np.int32)
        index = np.zeros(self.max_batch, np.int32)
        valid = np.zeros(self.max_batch, np.int32)
        for s in live:
            req = self.slot_req[s]
            tokens[s, 0] = req.output[-1] if req.output \
                else int(req.prompt[-1])
            index[s] = self.slot_pos[s]
            valid[s] = 1
        batch = {"tokens": jnp.asarray(tokens)}
        if self.cfg.mrope:
            batch["positions3"] = self._positions3(index, 1)
        step_args = ()
        if self.paged:
            for s in live:
                self._ensure_writable(s, int(index[s]), int(index[s]) + 1)
            step_args = (jnp.asarray(self.block_tables),)
        with self._mesh_ctx():
            logits, self.caches = self._decode(
                self.params, self.caches, batch, jnp.asarray(index),
                jnp.asarray(valid), *step_args)
        logits = np.asarray(logits)
        for s in live:
            self.slot_pos[s] += 1
            self._emit_token(s, logits[s], decode_pass=True)

    def _speculative_pass(self, live):
        """One speculative cycle (DESIGN.md §19): draft up to ``k``
        greedy tokens per slot in a single launch, score the whole
        drafted chain in one ``[B, k+1]`` target verify call (the
        prefill-chunk window shape), then commit the longest
        target-faithful prefix per slot via rejection sampling
        (speculative.accept_tokens) — 1..k+1 tokens for two launches."""
        k = self.config.speculative_k
        spec = self.spec
        tokens = np.zeros((self.max_batch, 1), np.int32)
        index = np.zeros(self.max_batch, np.int32)
        # dead slots draft at limit -1: limit+1 = 0 gates off every cache
        # write (a paged dead slot's block table row would alias page 0)
        limit = np.full(self.max_batch, -1, np.int32)
        for s in live:
            req = self.slot_req[s]
            tokens[s, 0] = req.output[-1] if req.output \
                else int(req.prompt[-1])
            index[s] = self.slot_pos[s]
            # a cycle commits at most limit+1 tokens, so limit =
            # min(k, remaining-1) never drafts past the request budget
            # and every cache write stays inside the reserved extent
            limit[s] = min(k, req.max_new_tokens - len(req.output) - 1)
        batch = {"tokens": jnp.asarray(tokens)}
        d_args = (jnp.asarray(spec.block_tables),) if spec.paged else ()
        with self._mesh_ctx():
            drafted, spec.caches = spec._draft(
                spec.params, spec.caches, batch, jnp.asarray(index),
                jnp.asarray(limit), *d_args)
        drafted = np.asarray(drafted)                      # [B, k]
        win = np.zeros((self.max_batch, k + 1), np.int32)  # [t0, d_0..]
        win[:, 0] = tokens[:, 0]
        win[:, 1:] = drafted
        valid = np.maximum(limit + 1, 0)
        v_args = ()
        if self.paged:
            for s in live:
                lo = int(index[s])
                self._ensure_writable(s, lo, lo + int(valid[s]))
            v_args = (jnp.asarray(self.block_tables),)
        with self._mesh_ctx():
            logits, self.caches = self._verify(
                self.params, self.caches, {"tokens": jnp.asarray(win)},
                jnp.asarray(index), jnp.asarray(valid), *v_args)
        logits = np.asarray(logits)                        # [B, k+1, V]
        self.metrics.spec_cycles += 1
        for s in live:
            req = self.slot_req[s]
            lim = int(limit[s])
            committed = speculative_lib.accept_tokens(
                logits[s, :lim + 1], drafted[s, :lim],
                req.sampling or self.sampling, self._slot_rng[s])
            self.metrics.drafted_tokens += lim
            self.metrics.accepted_tokens += len(committed) - 1
            self.metrics.verify_tokens += lim + 1
            for tok in committed:
                self.slot_pos[s] += 1
                self._commit_token(s, int(tok), decode_pass=True)
                if self.slot_req[s] is None:   # retired mid-window
                    break

    def _emit_token(self, s: int, logits_row: np.ndarray, *,
                    decode_pass: bool):
        """Sample one token from a logits row and commit it — the plain
        (non-speculative) emission path.  Sampling goes through
        speculative.sample_token, the same primitive the speculative
        bonus/resample path uses, so both paths draw from identical
        per-slot distributions and rng streams."""
        req = self.slot_req[s]
        tok = speculative_lib.sample_token(
            logits_row, req.sampling or self.sampling, self._slot_rng[s])
        self._commit_token(s, tok, decode_pass=decode_pass)

    def _commit_token(self, s: int, tok: int, *, decode_pass: bool):
        """Append one already-chosen token to slot ``s``'s request:
        metrics, TTFT/TPOT stamps, and retirement (slot + page release,
        draft pages included) when the request hits max_new_tokens."""
        req = self.slot_req[s]
        req.output.append(int(tok))
        self.metrics.generated_tokens += 1
        if decode_pass:
            self.metrics.decode_tokens += 1
        if len(req.output) == 1:
            req.first_token_time = time.perf_counter()
            self.metrics.ttft_s.append(req.first_token_time
                                       - req.submit_time)
        if len(req.output) >= req.max_new_tokens:
            req.done = True
            req.finish_time = time.perf_counter()
            if len(req.output) > 1:
                self.metrics.tpot_s.append(
                    (req.finish_time - req.first_token_time)
                    / (len(req.output) - 1))
            self._finished.append(req)
            self.metrics.retired += 1
            self.slot_req[s] = None
            if self.paged:
                # page-level retirement: drop this slot's references only;
                # prefix-index pages keep their index ref and stay cached
                self._release_slot_pages(s)
            if self.spec is not None:
                self.spec.release_slot(s)

    # ------------------------------------------------------------------
    # Reporting / draining
    # ------------------------------------------------------------------

    @property
    def num_pending(self) -> int:
        return len(self._queue)

    @property
    def num_live(self) -> int:
        """Occupied batch slots (the Router's load term, with the queue)."""
        return sum(r is not None for r in self.slot_req)

    def take_finished(self) -> list:
        """Hand over every request retired since the last call (the Router
        collects after each fleet tick; run_to_completion uses it too)."""
        done, self._finished = self._finished, []
        return done

    def take_queued(self) -> list:
        """Drain the admission queue WITHOUT serving it: replica drain
        support — the Router re-routes these to other replicas while this
        engine's live slots retire."""
        queued, self._queue = list(self._queue), deque()
        return queued

    def plan_report(self):
        """Flat per-layer plan rows (path + KernelPlan.describe())."""
        return [{"layer": path, **plan.describe()}
                for path, plan in sorted(self.plans.items())]

    def capacity_report(self) -> dict:
        """Cache-capacity accounting: bytes per slot and admitted slots;
        paged engines add physical-vs-logical page counters (pool free /
        live / shared pages, prefix-hit and COW counts, DESIGN.md §18);
        speculative engines add a ``speculative`` section (draft
        precision + draft pool sizing, DESIGN.md §19)."""
        rep = {
            "kv_bits": getattr(self.cfg.quant, "kv_bits", 0) or 16,
            "cache_bytes_per_slot": self.cache_bytes_per_slot,
            "hbm_cache_budget": self.hbm_cache_budget,
            "slots": self.max_batch,
            "paged": self.paged,
        }
        if self.paged:
            rep.update(
                page_size=self.page_size,
                page_bytes=self.page_bytes,
                num_pages=self.num_pages,
                pages_per_slot=self.pages_per_slot,
                # logical slots max_batch vs what worst-case reservations
                # alone would fit — sharing lifts live slots above this
                guaranteed_slots=self.num_pages // self.pages_per_slot,
                peak_live_slot_count=self.peak_live_slots,
                prefix_sharing=self._share,
                **self.pool.report())
        if self.spec is not None:
            rep["speculative"] = self.spec.describe()
        if self.shard_plan is not None:
            rep["shard_plan"] = self.shard_plan.describe()
        return rep

    # ------------------------------------------------------------------
    # Paged-state serialization (Router drain/restore, DESIGN.md §18)
    # ------------------------------------------------------------------

    def export_paged_state(self):
        """(caches, pool_meta): the device-side page pools (every layer's
        paged KV leaves — the bytes behind the warm prefix cache) plus the
        pool's JSON-able bookkeeping.  Drain retires live slots first, so
        what survives is exactly the prefix index and its pages."""
        if not self.paged:
            raise ValueError("export_paged_state on an unpaged engine")
        return self.caches, self.pool.export_meta()

    def import_paged_state(self, caches, pool_meta: dict):
        """Adopt a drained engine's page pools + prefix index (restore
        path, inverse of :meth:`export_paged_state`).  Geometry must match
        this engine's construction — the Router rebuilds the engine from
        the same EngineConfig first."""
        if not self.paged:
            raise ValueError("import_paged_state on an unpaged engine")
        if (pool_meta["num_pages"] != self.num_pages
                or pool_meta["page_size"] != self.page_size):
            raise ValueError(
                f"paged-state geometry mismatch: checkpoint has "
                f"{pool_meta['num_pages']} pages x {pool_meta['page_size']} "
                f"rows, engine was built with {self.num_pages} x "
                f"{self.page_size}")
        self.caches = jax.tree.map(
            lambda tpl, leaf: jnp.asarray(leaf, tpl.dtype),
            self.caches, caches)
        if self.shard_plan is not None:
            self.caches = self.shard_plan.place_caches(
                self.caches, self.cfg, self.max_batch, paged=True)
        self.pool = pages_lib.PagePool.from_meta(pool_meta)

    def run_to_completion(self):
        """Drain queue + slots; returns every request retired since the
        last call.  Retirement is recorded at sample time (not via
        before/after slot snapshots), so a request admitted and finished
        within a single step() is still collected."""
        while self.step():
            pass
        return self.take_finished()

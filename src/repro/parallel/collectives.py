"""Distributed-optimization collectives.

* int8 gradient compression with error feedback — theme-consistent with the
  paper (quantize the wire, not just the weights).  Inside a pjit'd step the
  compress->decompress round-trip happens before the (implicit) gradient
  reduce-scatter, so the tensors that cross the ICI are int8 + fp32 scales.
  The quantization residual is carried in the train state and re-injected
  next step (error feedback), which provably preserves convergence for
  smooth objectives.

* all_gather_matmul — explicitly overlapped TP collective matmul
  (shard_map + ppermute ring), used by the §Perf collective-bound hillclimb.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def quantize_grad(g, block: int = 256):
    flat = g.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % block
    if pad:
        flat = jnp.pad(flat, (0, pad))
    xb = flat.reshape(-1, block)
    scale = jnp.maximum(jnp.max(jnp.abs(xb), axis=1, keepdims=True) / 127.0,
                        1e-12)
    q = jnp.clip(jnp.round(xb / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_grad(q, scale, shape):
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    n = 1
    for s in shape:
        n *= s
    return flat[:n].reshape(shape)


def compress_grads_with_feedback(grads, state):
    """int8-compress grads, carrying the residual in state['error_feedback'].

    Returns (decompressed grads, updated state).  When the state has no
    error_feedback entry the compression runs without feedback.
    """
    feedback = state.get("error_feedback")

    def comp(g, e):
        g32 = g.astype(jnp.float32) + (e if e is not None else 0.0)
        q, scale = quantize_grad(g32)
        deq = dequantize_grad(q, scale, g32.shape)
        resid = g32 - deq
        return deq, resid

    if feedback is None:
        outs = jax.tree.map(lambda g: comp(g, None), grads,
                            is_leaf=lambda x: isinstance(x, jnp.ndarray))
        deq = jax.tree.map(lambda t: t[0], outs,
                           is_leaf=lambda x: isinstance(x, tuple))
        return deq, state
    outs = jax.tree.map(comp, grads, feedback)
    deq = jax.tree.map(lambda t: t[0], outs,
                       is_leaf=lambda x: isinstance(x, tuple))
    resid = jax.tree.map(lambda t: t[1], outs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_state = dict(state)
    new_state["error_feedback"] = resid
    return deq, new_state


# ---------------------------------------------------------------------------
# Overlapped collective matmul (TP all-gather hidden behind partial matmuls)
# ---------------------------------------------------------------------------

def all_gather_matmul(x, w, mesh, axis: str = "model"):
    """y = all_gather(x, axis) @ w, as a ppermute ring that overlaps each
    gather hop with the matmul of the shard already in hand.

    x: [m, k/P] sharded on its last dim over `axis`; w: [k/P, n] sharded on
    its first dim.  Returns y [m, n] replicated over `axis`.
    """
    from repro.parallel.sharding import shard_map

    p = mesh.shape[axis]

    def local(x_l, w_l):
        idx = jax.lax.axis_index(axis)
        perm = [(i, (i + 1) % p) for i in range(p)]

        def body(i, carry):
            acc, blk = carry
            # after i hops of the (s -> s+1) ring, device idx holds the
            # x-shard that originated on device (idx - i) mod p
            src = (idx - i) % p
            w_i = jax.lax.dynamic_slice_in_dim(
                w_full, src * w_l.shape[0], w_l.shape[0], 0)
            acc = acc + jnp.dot(blk, w_i)
            blk = jax.lax.ppermute(blk, axis, perm)
            return acc, blk

        # gather w once per device (weights stationary, small for TP shards)
        w_full = jax.lax.all_gather(w_l, axis, axis=0, tiled=True)
        acc0 = jnp.zeros((x_l.shape[0], w_l.shape[1]), x_l.dtype)
        acc, _ = jax.lax.fori_loop(0, p, body, (acc0, x_l))
        return acc

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(None, axis), P(axis, None)),
        out_specs=P(None, None), check_vma=False)(x, w)

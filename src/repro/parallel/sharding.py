"""Sharding rules: parameter-path -> PartitionSpec (DP/FSDP/TP/EP/SP).

Scheme (DESIGN.md §6):
  * FSDP axes  = ('data',) or ('pod', 'data') (cfg.parallel.fsdp_over_pod):
    parameters and optimizer state shard their largest non-TP dim here
    (ZeRO-3); XLA all-gathers at use and reduce-scatters gradients.
  * TP axis    = 'model': Megatron column/row pairs; embedding & logits shard
    the (padded) vocab dim.
  * EP         : expert dim shards over 'model' when num_experts divides the
    axis (jamba 16e); otherwise experts are FSDP + TP-within-expert
    (mixtral 8e).
  * SP         : long_500k shards KV-cache sequence over 'data'.

Every rule is divisibility-guarded: an axis that does not divide the tensor
dim is dropped (replicated) rather than producing an invalid sharding — the
dry-run asserts the *important* dims did shard (see tests/test_sharding.py).
"""

from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """``jax.shard_map`` across jax versions.

    The public ``jax.shard_map`` (with its ``check_vma`` replication check)
    only exists from jax 0.5; on the pinned 0.4.x toolchain the same
    transform lives at ``jax.experimental.shard_map.shard_map`` and spells
    the flag ``check_rep``.  Every shard_map in the repo routes through
    here so multi-device code (pipeline, collectives, sharded-vocab embed)
    runs on both — the seed-failing subprocess lowerings were exactly this
    AttributeError."""
    try:
        from jax import shard_map as sm
    except ImportError:
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=check_vma)
    return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
              check_vma=check_vma)


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        n = 1
        for a in axis:
            n *= mesh.shape[a]
        return n
    return mesh.shape[axis]


def _guard(mesh: Mesh, shape, spec: P) -> P:
    """Drop axes that do not divide the corresponding dim."""
    out = []
    for dim, axis in zip(shape, tuple(spec) + (None,) * (len(shape)
                                                         - len(spec))):
        if axis is None:
            out.append(None)
        elif dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        elif isinstance(axis, (tuple, list)):
            # try a prefix of the compound axis
            kept = [a for a in axis if dim % _axis_size(mesh, (a,)) == 0]
            out.append(tuple(kept[:1]) if kept else None)
        else:
            out.append(None)
    return P(*out)


# ---------------------------------------------------------------------------
# Activation sharding hints.  The model code calls constrain() at the few
# places where SPMD propagation needs help (post-embedding, logits, MoE
# dispatch); outside a mesh context it is a no-op so single-host tests and
# examples run unchanged.
# ---------------------------------------------------------------------------

_ACTIVE_MESH: list = [None]


class activation_mesh:
    """Context manager announcing the physical mesh to constrain()."""

    def __init__(self, mesh):
        self.mesh = mesh

    def __enter__(self):
        _ACTIVE_MESH.append(self.mesh)
        return self.mesh

    def __exit__(self, *exc):
        _ACTIVE_MESH.pop()


def constrain(x, *axes):
    """with_sharding_constraint(x, P(axes...)) with 'dp' meta-axis resolution
    and divisibility guarding; no-op without an active mesh."""
    mesh = _ACTIVE_MESH[-1]
    if mesh is None:
        return x
    resolved = []
    for a in axes:
        if a == "dp":
            dp = tuple(ax for ax in ("pod", "data") if ax in mesh.shape)
            resolved.append(dp if dp else None)
        else:
            resolved.append(a)
    spec = _guard(mesh, x.shape, P(*resolved))
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec))


def constrain_like_params(tree, cfg):
    """Constrain a param-shaped pytree (grads, accumulators) to the param
    sharding rules — keeps scan-carried gradient accumulators sharded instead
    of silently replicating (a multi-GB difference at jamba scale)."""
    mesh = _ACTIVE_MESH[-1]
    if mesh is None:
        return tree

    def one(path, leaf):
        spec = param_pspec(path_str(path), leaf, cfg, mesh)
        return jax.lax.with_sharding_constraint(
            leaf, NamedSharding(mesh, spec))

    return jax.tree_util.tree_map_with_path(one, tree)


def path_str(path) -> str:
    parts = []
    for e in path:
        if hasattr(e, "key"):
            parts.append(str(e.key))
        elif hasattr(e, "idx"):
            parts.append(str(e.idx))
        else:
            parts.append(str(e))
    return "/".join(parts)


# (regex, spec factory(fsdp, tp, ep)) — first match wins.
_RULES = [
    # packed serving weights (same layout roles as their kernels)
    (r"(o|down|out_proj|ffn_down)/col_sums$", lambda f, t, e: P(None)),
    (r"col_sums$",               lambda f, t, e: P(t)),
    (r"(w_scale|a_scale|w_zp|a_zp)$", lambda f, t, e: P()),
    (r"lm_head/kernel$",         lambda f, t, e: P(f, t)),
    (r"frontend_proj/kernel$",   lambda f, t, e: P(None, f)),
    # MoE experts [E, din, dout]
    (r"moe/(up|gate)/kernel$",
     lambda f, t, e: P(t, f, None) if e else P(None, f, t)),
    (r"moe/down/kernel$",
     lambda f, t, e: P(t, None, f) if e else P(None, t, f)),
    (r"moe/(up|gate|down)/(w_step|a_step)$", lambda f, t, e: P()),
    (r"moe/router/kernel$",      lambda f, t, e: P(None, None)),
    # column-parallel projections [din, dout]
    (r"(attn|cross)/(q|k|v)/kernel$", lambda f, t, e: P(f, t)),
    (r"(attn|cross)/(q|k|v)/bias$",   lambda f, t, e: P(t)),
    (r"(mlp|moe)?/?(up|gate)/kernel$", lambda f, t, e: P(f, t)),
    (r"(in_proj|w_gates|ffn_up|up|gate|q|k|v)/kernel$",
     lambda f, t, e: P(f, t)),
    (r"(in_proj|w_gates|ffn_up|up|gate)/bias$", lambda f, t, e: P(t)),
    # row-parallel projections [dout_tp, d]
    (r"(o|down|out_proj|ffn_down)/kernel$", lambda f, t, e: P(t, f)),
    (r"(o|down|out_proj|ffn_down)/bias$",   lambda f, t, e: P(None)),
    # mamba internals
    (r"conv_w$",                 lambda f, t, e: P(None, t)),
    (r"(conv_b|D)$",             lambda f, t, e: P(t)),
    (r"A_log$",                  lambda f, t, e: P(t, None)),
    (r"x_proj/kernel$",          lambda f, t, e: P(t, None)),
    (r"dt_proj/kernel$",         lambda f, t, e: P(None, t)),
    (r"dt_proj/bias$",           lambda f, t, e: P(t)),
    # xLSTM gates
    (r"if_gate/kernel$",         lambda f, t, e: P(t, None)),
    (r"if_gate/bias$",           lambda f, t, e: P(None)),
    (r"r_gates$",                lambda f, t, e: P(None)),
    # norms / steps / scalars / cnn
    (r"(norm\w*|final_norm)/(scale|bias)$", lambda f, t, e: P(None)),
    (r"(w_step|a_step|alpha)$",  lambda f, t, e: P()),
    (r"(stem|layers/\d+)/kernel$", lambda f, t, e: P(None)),
    (r"head/kernel$",            lambda f, t, e: P(None, None)),
]


def param_pspec(path: str, leaf, cfg, mesh: Mesh) -> P:
    fsdp = (("pod", "data") if (cfg.parallel.fsdp_over_pod
                                and "pod" in mesh.shape) else ("data",))
    tp = "model"
    ep = cfg.parallel.expert_parallel and \
        cfg.num_experts > 0 and cfg.num_experts % mesh.shape[tp] == 0
    # packed weights take their kernel's rule
    path = re.sub(r"/w_packed$", "/kernel", path)
    # embedding: tied tables shard vocab over TP (logits matmul wants it);
    # untied tables shard d_model (gather-friendly, head handles logits)
    if re.search(r"embed/table$", path):
        spec = P(tp, None) if cfg.tie_embeddings else P(tp, fsdp)
        return _guard(mesh, np.shape(leaf), spec)
    for pat, fac in _RULES:
        if re.search(pat, path):
            spec = fac(fsdp, tp, ep)
            return _guard(mesh, np.shape(leaf), spec)
    # default: shard the largest dim over FSDP if divisible
    shape = np.shape(leaf)
    if not shape:
        return P()
    big = int(np.argmax(shape))
    spec = [None] * len(shape)
    spec[big] = fsdp
    return _guard(mesh, shape, P(*spec))


def param_shardings(params, cfg, mesh: Mesh):
    """Pytree of NamedSharding matching `params` (works on ShapeDtypeStructs
    as well as real arrays — used by the dry-run)."""
    def one(path, leaf):
        return NamedSharding(mesh, param_pspec(path_str(path), leaf, cfg,
                                               mesh))
    return jax.tree_util.tree_map_with_path(one, params)


def opt_state_shardings(opt_state, param_shardings_tree, cfg, mesh: Mesh):
    """Optimizer moments inherit the parameter sharding; 8-bit moment blocks
    ([nblocks, block] reshaped) fall back to FSDP on dim 0; counters
    replicate."""
    fsdp = (("pod", "data") if (cfg.parallel.fsdp_over_pod
                                and "pod" in mesh.shape) else ("data",))

    def one(path, leaf):
        ps = path_str(path)
        shape = np.shape(leaf)
        if ps.endswith("count") or not shape:
            return NamedSharding(mesh, P())
        if ps.endswith("/q") or ps.endswith("/scale"):
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(fsdp,
                                                *([None] * (len(shape) - 1)))))
        # fp32 moments: mirror the param rule by stripping the m/v prefix
        stripped = re.sub(r"^(m|v)/", "", ps)
        return NamedSharding(mesh, param_pspec(stripped, leaf, cfg, mesh))

    return jax.tree_util.tree_map_with_path(one, opt_state)


def batch_pspec(cfg, mesh: Mesh, global_batch: int) -> P:
    """Leading batch-dim sharding for inputs: ('pod','data') when divisible."""
    dp = [a for a in ("pod", "data") if a in mesh.shape]
    keep = []
    size = 1
    for a in dp:
        if global_batch % (size * mesh.shape[a]) == 0:
            keep.append(a)
            size *= mesh.shape[a]
    return P(tuple(keep) if keep else None)


def batch_shardings(batch, cfg, mesh: Mesh, global_batch: int):
    bp = batch_pspec(cfg, mesh, global_batch)

    def one(path, leaf):
        shape = np.shape(leaf)
        if not shape:
            return NamedSharding(mesh, P())
        if path_str(path).endswith("positions3"):  # [3, B, S]
            return NamedSharding(mesh, _guard(mesh, shape, P(None, *bp)))
        return NamedSharding(mesh, _guard(mesh, shape, bp))

    return jax.tree_util.tree_map_with_path(one, batch)


def cache_shardings(caches, cfg, mesh: Mesh, global_batch: int,
                    sequence_parallel: bool = False,
                    kv_head_shard: bool = False, paged: bool = False):
    """KV/state cache sharding.  decode_32k: batch over DP.  long_500k
    (batch=1): sequence over 'data' (SP) and head_dim over 'model'.

    ``kv_head_shard=True`` is the serving-TP layout (serve/shard.ShardPlan,
    DESIGN.md §15): attention K/V shard the kv-head axis (axis 2 of
    [B, S, KVH, hd]) over 'model' and the per-(pos, kv-head) scale planes
    [B, S, KVH] shard the same axis — valid for every storage precision
    cfg.quant.kv_bits selects, because quantization, word-packing and
    fused-dequant reads are all per-(pos, kv-head) local: a sub-byte
    cache's int32 words pack along head_dim *within* one kv head, so a
    head shard holds whole, locally-decodable words.  Head-dim sharding
    (the training default below) would instead split words across devices
    for packed caches and replicate the cache whenever kv_heads < axis
    size.

    ``paged=True`` (with ``kv_head_shard``) is the same layout over a page
    pool (DESIGN.md §18): attention leaves are ``[P, page_size, KVH, ...]``
    — the kv-head axis is still axis 2, so the 'model' shard rule carries
    over unchanged, but the leading *page* axis replicates rather than
    taking the batch axis: pages are a shared physical resource every
    slot's block table may reference, not per-sequence rows."""
    bp = batch_pspec(cfg, mesh, global_batch)
    bp0 = bp[0] if len(bp) else None
    if paged:
        bp0 = None

    import os
    seq_shard = os.environ.get("REPRO_KV_SEQ_SHARD", "0") == "1"

    def one(path, leaf):
        ps = path_str(path)
        shape = np.shape(leaf)
        if leaf is None or not shape:
            return NamedSharding(mesh, P())
        if re.search(r"attn/(k_scale|v_scale)$", ps):
            if kv_head_shard:
                return NamedSharding(mesh, _guard(mesh, shape,
                                                  P(bp0, None, "model")))
            seq_ax = "model" if seq_shard else None
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(bp0, seq_ax, None)))
        if kv_head_shard and re.search(r"attn/(k|v)$", ps):
            return NamedSharding(mesh, _guard(
                mesh, shape, P(bp0, None, "model", None)))
        if re.search(r"attn/(k|v)$", ps) or re.search(r"cross_kv", ps):
            if seq_shard:
                # canonical decode pattern: KV sharded over sequence,
                # q replicated over 'model'; softmax stats all-reduce.
                # head-dim sharding (the baseline) forces SPMD to replicate
                # the cache when kv_heads < axis size (§Perf cell C iter 3).
                seq_axes = ("data", "model") if sequence_parallel                     else "model"
                return NamedSharding(mesh, _guard(
                    mesh, shape, P(bp0, seq_axes, None, None)))
            if sequence_parallel:
                return NamedSharding(mesh, _guard(
                    mesh, shape, P(bp0, "data", None, "model")))
            return NamedSharding(mesh, _guard(
                mesh, shape, P(bp0, None, None, "model")))
        if ps.endswith("mamba/conv"):
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(bp0, None, "model")))
        if ps.endswith("mamba/ssm"):
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(bp0, "model", None)))
        if ps.endswith("mlstm/C"):
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(bp0, None, "model", None)))
        if ps.endswith("mlstm/n") or re.search(r"slstm/(c|n|h|m)$", ps):
            return NamedSharding(mesh, _guard(mesh, shape,
                                              P(bp0, None, "model")))
        if ps.endswith("mlstm/m"):
            return NamedSharding(mesh, _guard(mesh, shape, P(bp0, None)))
        return NamedSharding(mesh, _guard(mesh, shape, P(bp0)))

    return jax.tree_util.tree_map_with_path(
        one, caches, is_leaf=lambda x: x is None)

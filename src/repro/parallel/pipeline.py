"""GPipe-style pipeline parallelism over a mesh axis (the pod axis).

At jamba-398B scale the pod axis can serve as a 2-stage pipeline instead of
extra FSDP: each pod holds half the layers and microbatches flow through a
ppermute ring.  FSDP+TP remains the default on TPU (DESIGN.md §6); this
module provides the PP option and is exercised by tests/test_pipeline.py on
a host mesh with 2 forced devices.

Schedule: classic GPipe fill-drain over T = n_micro + n_stages - 1 ticks.
Stage s computes microbatch m at tick t = s + m; activations hop one stage
per tick via collective_permute.  Bubble fraction = (P-1)/(T) — reported by
``bubble_fraction`` so launch configs can size n_micro.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def bubble_fraction(n_micro: int, n_stages: int) -> float:
    return (n_stages - 1) / (n_micro + n_stages - 1)


def gpipe(stage_fn, stage_params, xs, *, mesh, axis: str = "pod"):
    """Run ``xs`` microbatches through a pipeline along ``axis``.

    stage_fn(params, x) -> y: one stage's computation; activation shape is
    preserved across stages (transformer blocks).
    stage_params: pytree whose leaves have a leading stage dim == axis size
    (stage s's slice lives on pod s).
    xs: [n_micro, mb, ...] microbatched inputs (replicated over `axis`).
    Returns [n_micro, mb, ...] outputs.
    """
    n_stages = mesh.shape[axis]
    n_micro = xs.shape[0]
    ticks = n_micro + n_stages - 1
    perm = [(i, i + 1) for i in range(n_stages - 1)]   # stage s -> s+1

    def local(params_s, xs_l):
        stage = jax.lax.axis_index(axis)
        params_s = jax.tree.map(lambda a: a[0], params_s)  # drop stage dim
        buf = jnp.zeros_like(xs_l[0])                      # in-flight act
        outs = jnp.zeros_like(xs_l)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 injects microbatch t (if still filling)
            m_in = jnp.clip(t, 0, n_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(xs_l, m_in, 0,
                                                  keepdims=False)
            x = jnp.where(stage == 0, inject, buf)
            active = (t - stage >= 0) & (t - stage < n_micro)
            y = stage_fn(params_s, x)
            y = jnp.where(active, y, buf)
            # last stage collects microbatch t - (P-1)
            m_out = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            collect = (stage == n_stages - 1) & active
            outs = jax.lax.cond(
                collect,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, y, m_out, 0),
                lambda o: o, outs)
            # hop activations one stage forward
            buf = jax.lax.ppermute(y, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, ticks, tick, (buf, outs))
        # outputs live on the last stage: broadcast to all stages
        outs = jax.lax.psum(
            jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs)),
            axis)
        return outs

    from repro.parallel.sharding import shard_map

    other_axes = [a for a in mesh.axis_names if a != axis]
    del other_axes
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P(*([None] * xs.ndim))),
        out_specs=P(*([None] * xs.ndim)),
        check_vma=False)(stage_params, xs)

"""Restartable training loop with checkpoint/restart fault tolerance,
preemption handling, straggler detection, and elastic resume.

The loop is a state machine around (state, data step): every side effect
needed to resume — parameters, optimizer, PRNG, data position — lives in the
checkpoint, so `run()` after ANY crash/preemption resumes bit-identically
(tests/test_fault.py kills and resumes mid-run).

Straggler mitigation: per-step wall-time is tracked against a rolling median;
a step slower than `straggler_factor` x median raises a StragglerEvent to the
supplied callback — on a real cluster that triggers hot-spare swap or
grad-accumulation rebalance; here it is surfaced + logged (and tested with an
injected delay).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from pathlib import Path
from typing import Callable, Optional

import jax
import numpy as np

from repro.data.pipeline import DataConfig, SyntheticLMStream
from repro.launch import steps as steps_lib
from repro.models import lm
from repro.train import checkpoint


@dataclasses.dataclass
class TrainLoopConfig:
    total_steps: int = 100
    checkpoint_every: int = 50
    checkpoint_dir: str = "checkpoints"
    keep_checkpoints: int = 3
    log_every: int = 10
    straggler_factor: float = 3.0
    async_checkpoint: bool = True


class StragglerEvent(Exception):
    pass


class Trainer:
    def __init__(self, cfg, loop_cfg: TrainLoopConfig, data_cfg: DataConfig,
                 *, mesh=None, seed: int = 0,
                 straggler_cb: Optional[Callable] = None,
                 train_step_kwargs: Optional[dict] = None):
        self.cfg = cfg
        self.loop_cfg = loop_cfg
        self.data = SyntheticLMStream(data_cfg)
        self.mesh = mesh
        self.seed = seed
        self.straggler_cb = straggler_cb or (lambda info: None)
        self._preempted = False
        self._ckpt_join = lambda: None
        self.step_fn = jax.jit(steps_lib.make_train_step(
            cfg, **(train_step_kwargs or {})))
        self.metrics_log: list = []

    # ---- fault-tolerance hooks ----
    def install_preemption_handler(self, sig=signal.SIGTERM):
        """SIGTERM (cluster preemption notice) -> synchronous checkpoint at
        the next step boundary, then clean exit."""
        signal.signal(sig, lambda *_: setattr(self, "_preempted", True))

    def _init_state(self):
        params = lm.init_params(jax.random.PRNGKey(self.seed), self.cfg)
        return steps_lib.make_train_state(params, cfg=self.cfg)

    def _resume_or_init(self):
        ckdir = Path(self.loop_cfg.checkpoint_dir)
        last = checkpoint.latest_step(ckdir)
        template = jax.eval_shape(self._init_state)
        if last is None:
            return self._init_state(), 0
        state, manifest = checkpoint.restore(ckdir, template, step=last)
        return state, int(manifest["step"])

    def _save(self, state, step, blocking=False):
        self._ckpt_join()  # one async save in flight at a time
        self._ckpt_join = checkpoint.save(
            self.loop_cfg.checkpoint_dir, state, step=step,
            extra={"data_state": self.data.state(step),
                   "config_name": self.cfg.name},
            async_=self.loop_cfg.async_checkpoint and not blocking)
        checkpoint.garbage_collect(self.loop_cfg.checkpoint_dir,
                                   self.loop_cfg.keep_checkpoints)

    # ---- main loop ----
    def run(self):
        state, start = self._resume_or_init()
        durations: list = []
        for step in range(start, self.loop_cfg.total_steps):
            batch = self.data.batch_at(step)
            batch = jax.tree.map(jax.numpy.asarray, batch)
            t0 = time.time()
            state, metrics = self.step_fn(state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            durations.append(dt)
            med = float(np.median(durations[-20:]))
            if len(durations) > 5 and dt > self.loop_cfg.straggler_factor \
                    * med:
                self.straggler_cb({"step": step, "duration": dt,
                                   "median": med})
            if step % self.loop_cfg.log_every == 0 or \
                    step == self.loop_cfg.total_steps - 1:
                row = {k: float(v) for k, v in metrics.items()}
                row["step"] = step
                row["s_per_step"] = dt
                self.metrics_log.append(row)
                print(f"step {step:5d} loss {row['loss']:.4f} "
                      f"ce {row['ce']:.4f} gnorm {row['grad_norm']:.3f} "
                      f"({dt:.2f}s)")
            done = step + 1
            if done % self.loop_cfg.checkpoint_every == 0:
                self._save(state, done)
            if self._preempted:
                print(f"[preempted] checkpointing at step {done} and "
                      "exiting cleanly")
                self._save(state, done, blocking=True)
                self._ckpt_join()
                return state, done
        self._save(state, self.loop_cfg.total_steps, blocking=True)
        self._ckpt_join()
        return state, self.loop_cfg.total_steps

"""Sharded, elastic, async checkpointing (no TensorStore in this container).

Layout:
  <dir>/step_<n>/manifest.json     — step, config name, mesh shape, data
                                     state, PRNG, tree structure
  <dir>/step_<n>/arrays/<leaf>.npy — one file per pytree leaf (addressable
                                     data gathered per leaf; a real multi-host
                                     deployment writes one file per shard —
                                     the manifest records the layout either
                                     way)
  <dir>/step_<n>/COMMITTED         — atomic-commit marker (crash-consistent:
                                     restore ignores uncommitted steps)

Elastic restore: arrays are loaded host-side and re-sharded with
jax.device_put against the *current* mesh, so restarts may change mesh shape
or data-parallel degree (tests/test_checkpoint.py covers reshard equality).
Async: save runs on a background thread off a host-side snapshot.
"""

from __future__ import annotations

import json
import shutil
import threading
from pathlib import Path

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(e, "key", getattr(e, "idx", e)))
                        for e in path)
        out.append((name, leaf))
    return out


def save(directory, state, *, step: int, extra: dict | None = None,
         async_: bool = False):
    """Checkpoint `state` (pytree).  Returns a join() callable."""
    directory = Path(directory)
    tmp = directory / f"step_{step}.tmp"
    final = directory / f"step_{step}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True, exist_ok=True)

    # snapshot to host memory NOW (training may mutate buffers after return)
    leaves = [(name, np.asarray(leaf)) for name, leaf in
              _flatten_with_paths(state)]
    treedef = jax.tree_util.tree_structure(state)

    def write():
        arr_dir = tmp / "arrays"
        arr_dir.mkdir(exist_ok=True)
        names = []
        for i, (name, arr) in enumerate(leaves):
            fn = f"{i:05d}.npy"
            np.save(arr_dir / fn, arr)
            names.append({"name": name, "file": fn,
                          "dtype": str(arr.dtype), "shape": list(arr.shape)})
        manifest = {"step": step, "leaves": names,
                    "treedef": str(treedef), **(extra or {})}
        (tmp / "manifest.json").write_text(json.dumps(manifest, indent=1))
        (tmp / "COMMITTED").touch()
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)

    if async_:
        th = threading.Thread(target=write, daemon=True)
        th.start()
        return th.join
    write()
    return lambda: None


def latest_step(directory) -> int | None:
    directory = Path(directory)
    if not directory.exists():
        return None
    steps = []
    for d in directory.iterdir():
        if d.name.startswith("step_") and not d.name.endswith(".tmp") \
                and (d / "COMMITTED").exists():
            steps.append(int(d.name.split("_")[1]))
    return max(steps) if steps else None


def restore(directory, state_template, *, step: int | None = None,
            shardings=None):
    """Restore into the structure of `state_template`.

    `shardings` (optional pytree of NamedSharding) re-shards each leaf for
    the CURRENT mesh — elastic restarts re-partition here.
    Returns (state, manifest).
    """
    directory = Path(directory)
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    d = directory / f"step_{step}"
    manifest = json.loads((d / "manifest.json").read_text())

    def load(leaf):
        arr = np.load(d / "arrays" / leaf["file"])
        if arr.dtype.kind == "V":
            # extension dtypes (bfloat16 via ml_dtypes) round-trip through
            # npy as raw void bytes; the manifest records the real dtype
            arr = arr.view(np.dtype(leaf["dtype"]))
        return arr

    arrays = [load(leaf) for leaf in manifest["leaves"]]
    treedef = jax.tree_util.tree_structure(state_template)
    if treedef.num_leaves != len(arrays):
        raise ValueError(
            f"checkpoint has {len(arrays)} leaves, template expects "
            f"{treedef.num_leaves} — config mismatch?")
    state = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        state = jax.tree.map(
            lambda x, s: jax.device_put(x, s), state, shardings)
    else:
        state = jax.tree.map(jax.numpy.asarray, state)
    return state, manifest


def garbage_collect(directory, keep: int = 3):
    directory = Path(directory)
    if not directory.exists():
        return
    steps = sorted(
        int(d.name.split("_")[1]) for d in directory.iterdir()
        if d.name.startswith("step_") and not d.name.endswith(".tmp")
        and (d / "COMMITTED").exists())
    for s in steps[:-keep]:
        shutil.rmtree(directory / f"step_{s}", ignore_errors=True)

"""Ahead-of-time kernel planning + backend registry (paper §IV philosophy).

Sparq commits to one execution plan per layer *offline*: pack layout,
shift-extract cadence and accumulator spill distance are all fixed before the
first input arrives (same philosophy as FullPack's ahead-of-time lane layout
planning).  This module is the TPU-side analogue: a ``KernelPlan`` is a frozen,
hashable description of how one op will execute — backend, ``PackSpec``, tile
sizes, and weight-storage mode — built once per layer by a planner that
inspects shapes, the device, and the VMEM budget (DESIGN.md §11).

Three pieces:

  * ``KernelPlan``   — the frozen dataclass.  Hashable, so it can be an
                       ``lru_cache`` key / jit static argument.
  * planners         — ``plan_packed_matmul`` / ``plan_packed_conv2d`` /
                       ``plan_quantize_pack`` / ``plan_int_matmul``.  All are
                       ``lru_cache``d: a layer's plan is built exactly once per
                       process for a given shape signature.
  * backend registry — ``register_backend(op, backend)`` decorates an
                       implementation; ``dispatch(plan, *args)`` routes a call.
                       kernels/ops.py registers 'pallas' and 'xla' entries for
                       every public op and contains no ad-hoc resolution.

Weight-storage modes (``KernelPlan.weight_store``):
  'lanes' — P1-packed lanes (spec.lane_dtype), the default deployed layout.
  'dense' — bit-dense int32 words (true w_bits/value HBM footprint); the
            conv2d Pallas kernel expands words -> P1 lanes in its VMEM
            prologue, the XLA fallback expands at trace level.  ``k_full``
            records the unpacked contraction length (K, or Cin for conv) the
            expansion must recover.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import PackSpec
from repro.roofline import hw

#: Fraction of per-core VMEM the planner will budget for one kernel's working
#: set; the rest is headroom for double buffering and compiler temporaries.
VMEM_FRACTION = 0.5

_CONV_BLOCK_H_CANDIDATES = (256, 128, 64, 32, 16, 8, 4, 2, 1)


def default_interpret() -> bool:
    """Pallas kernels run interpreted off-TPU (CPU validation mode).

    This is the default everywhere — the KernelPlan field and every direct
    kernel entry point resolve ``interpret`` from it, so a hand-built plan
    or ad-hoc kernel call on a real TPU compiles instead of silently
    falling into the (orders-of-magnitude slower) Pallas interpreter."""
    return jax.default_backend() != "tpu"


@dataclasses.dataclass(frozen=True)
class KernelPlan:
    """Frozen per-layer execution plan; see module docstring.

    Tile fields are populated per-op (``None`` where not applicable):
      packed_matmul / int_matmul : block_m, block_n, chunks / block_k
      packed_conv2d              : block_h, block_co
      quantize_pack              : block_m, block_k
    """

    op: str
    backend: str                      # 'pallas' | 'xla' (never 'auto')
    spec: PackSpec | None = None
    interpret: bool = dataclasses.field(default_factory=default_interpret)
    weight_store: str = "lanes"       # 'lanes' | 'dense'
    k_full: int | None = None         # unpacked K (dense expansion target)
    block_m: int | None = None
    block_n: int | None = None
    block_k: int | None = None
    chunks: int | None = None
    block_h: int | None = None
    block_co: int | None = None
    vmem_bytes: int = 0               # planner working-set estimate
    source: str = "heuristic"         # 'heuristic' | 'tuned' | 'manual'

    def __post_init__(self):
        if self.backend not in ("pallas", "xla"):
            raise ValueError(f"unresolved backend {self.backend!r}")
        if self.weight_store not in ("lanes", "dense"):
            raise ValueError(f"unknown weight_store {self.weight_store!r}")
        if self.weight_store == "dense" and self.k_full is None:
            raise ValueError("dense weight storage requires k_full")
        if self.source not in ("heuristic", "tuned", "manual"):
            raise ValueError(f"unknown plan source {self.source!r}")

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / hw.VMEM_PER_CORE

    def describe(self) -> dict:
        """Flat report row for benchmarks / the serving engine."""
        d = {"op": self.op, "backend": self.backend,
             "spec": str(self.spec) if self.spec else "",
             "weight_store": self.weight_store,
             "source": self.source,
             "vmem_bytes": self.vmem_bytes,
             "vmem_frac": round(self.vmem_fraction, 4)}
        for f in ("block_m", "block_n", "block_k", "chunks", "block_h",
                  "block_co", "k_full"):
            v = getattr(self, f)
            if v is not None:
                d[f] = v
        return d

    def __str__(self):
        tiles = ",".join(f"{f}={getattr(self, f)}"
                         for f in ("block_m", "block_n", "block_k", "chunks",
                                   "block_h", "block_co")
                         if getattr(self, f) is not None)
        spec = f" {self.spec}" if self.spec else ""
        src = "" if self.source == "heuristic" else f" {self.source}"
        return (f"Plan[{self.op}/{self.backend}{spec} "
                f"store={self.weight_store} {tiles}{src}]")


# ---------------------------------------------------------------------------
# Backend registry
# ---------------------------------------------------------------------------

_BACKENDS: dict[tuple[str, str], object] = {}


def register_backend(op: str, backend: str):
    """Decorator: register ``fn(plan, *args)`` as the (op, backend) impl."""
    def deco(fn):
        _BACKENDS[(op, backend)] = fn
        return fn
    return deco


def get_backend(op: str, backend: str):
    try:
        return _BACKENDS[(op, backend)]
    except KeyError:
        known = sorted(k for k in _BACKENDS if k[0] == op)
        raise KeyError(
            f"no backend {backend!r} registered for op {op!r}; "
            f"registered: {known}") from None


def registered_ops():
    return sorted(_BACKENDS)


def dispatch(plan: KernelPlan, *args, **kwargs):
    """Route a call through the registry according to its plan."""
    return get_backend(plan.op, plan.backend)(plan, *args, **kwargs)


def resolve_backend(backend: str = "auto") -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if backend not in ("pallas", "xla"):
        raise ValueError(f"unknown backend {backend!r}")
    return backend


# ---------------------------------------------------------------------------
# Planners (all lru_cached: one plan per layer signature per process)
# ---------------------------------------------------------------------------

def _lane_bytes(spec: PackSpec) -> int:
    return jnp.dtype(spec.lane_dtype).itemsize


def matmul_working_set(bm: int, bn: int, chunks: int,
                       spec: PackSpec) -> int:
    """ulppack_matmul VMEM accounting: (bm*bk + bk*bn) lanes +
    (chunks+1)*bm*bn s32 accumulator/output tiles."""
    bk = chunks * spec.k_tile
    return (bm * bk + bk * bn) * _lane_bytes(spec) + \
        (chunks + 1) * bm * bn * 4


def conv2d_working_set(block_h: int, block_co: int, *, fh: int, fw: int,
                       w: int, cp: int, cdim: int, out_w: int,
                       spec: PackSpec, weight_store: str) -> int:
    """ulppack_conv2d VMEM accounting: halo-overlapped input tile + weight
    block + s32 accumulator/output tiles (``w`` is the padded input
    width)."""
    lb = _lane_bytes(spec)
    w_bytes = fh * fw * cdim * block_co * \
        (4 if weight_store == "dense" else lb)
    x_tile = (block_h + fh - 1) * w * cp * lb
    acc_out = 2 * block_h * out_w * block_co * 4
    return x_tile + w_bytes + acc_out


def attention_decode_working_set(block_k: int, kvh: int, hd: int,
                                 groups: int) -> int:
    """ulppack_attention per-program VMEM accounting: one KV group's
    unpacked K + V f32 planes, the [KVH, G, block_k] score block, and the
    (m, l, acc) online-softmax carry."""
    return (2 * block_k * kvh * hd * 4 + kvh * groups * block_k * 4
            + kvh * groups * (hd + 2) * 4)


def _tuned_entry(key: str, budget: int, ws_ok) -> dict | None:
    """Consult the active autotune cache; entries whose tiles no longer fit
    the VMEM budget (stale cache, changed budget) are ignored.  ``ws_ok``
    maps an entry to its working-set estimate or None when malformed."""
    from repro.kernels import autotune  # deferred: autotune imports plan

    entry = autotune.lookup(key)
    if entry is None:
        return None
    try:
        ws = ws_ok(entry)
    except (KeyError, TypeError, ValueError):
        return None
    if ws is None or ws > budget:
        return None
    return entry


@functools.lru_cache(maxsize=None)
def plan_packed_matmul(m: int, kp: int, n: int, spec: PackSpec, *,
                       backend: str = "auto", weight_store: str = "lanes",
                       k_full: int | None = None,
                       vmem_budget: int | None = None,
                       use_tuning_cache: bool = True) -> KernelPlan:
    """Plan a packed-lane matmul [m, kp] x [kp, n].

    The autotune cache (kernels/autotune.py) is consulted first: a hit whose
    tiles still fit the VMEM budget becomes the plan (``source='tuned'``).
    On miss, tile choice mirrors ulppack_matmul's VMEM accounting: working
    set ~= (bm*bk + bk*bn) lanes + (chunks+1)*bm*bn s32.  Defaults (128,
    128, chunks=8) are kept when they fit; otherwise chunks shrinks first
    (it only amortizes grid overhead), then bn, then bm.
    """
    spec.validate()   # beyond-bound layouts are rejected here, not in-kernel
    backend = resolve_backend(backend)
    if weight_store == "dense" and k_full is None:
        k_full = kp * spec.n_pack
    budget = vmem_budget or int(hw.VMEM_PER_CORE * VMEM_FRACTION)

    if use_tuning_cache:
        from repro.kernels import autotune
        entry = _tuned_entry(
            autotune.matmul_key(m, kp, n, spec, backend=backend,
                                weight_store=weight_store),
            budget,
            lambda e: matmul_working_set(int(e["block_m"]),
                                         int(e["block_n"]),
                                         int(e["chunks"]), spec))
        if entry is not None:
            bm, bn, chunks = (int(entry["block_m"]), int(entry["block_n"]),
                              int(entry["chunks"]))
            return KernelPlan(
                op="packed_matmul", backend=backend, spec=spec,
                interpret=default_interpret(), weight_store=weight_store,
                k_full=k_full, block_m=bm, block_n=bn, chunks=chunks,
                vmem_bytes=matmul_working_set(bm, bn, chunks, spec),
                source="tuned")

    def working_set(bm, bn, chunks):
        return matmul_working_set(bm, bn, chunks, spec)

    bm, bn, chunks = 128, 128, 8
    while chunks > 1 and working_set(bm, bn, chunks) > budget:
        chunks //= 2
    while bn > 8 and working_set(bm, bn, chunks) > budget:
        bn //= 2
    while bm > 8 and working_set(bm, bn, chunks) > budget:
        bm //= 2
    return KernelPlan(
        op="packed_matmul", backend=backend, spec=spec,
        interpret=default_interpret(), weight_store=weight_store,
        k_full=k_full, block_m=bm, block_n=bn, chunks=chunks,
        vmem_bytes=working_set(bm, bn, chunks))


@functools.lru_cache(maxsize=None)
def plan_packed_conv2d(x_shape: tuple, w_shape: tuple, spec: PackSpec, *,
                       padding: str = "SAME", backend: str = "auto",
                       weight_store: str = "lanes", k_full: int | None = None,
                       block_h: int | None = None, block_co: int | None = None,
                       vmem_budget: int | None = None,
                       use_tuning_cache: bool = True) -> KernelPlan:
    """Plan a packed conv2d: x [N, H, W, Cp] * w [Fh, Fw, Cdim, Co].

    The autotune cache is consulted first (unless the caller pins tiles with
    ``block_h``/``block_co``): a hit whose tiles fit the VMEM budget becomes
    the plan (``source='tuned'``).  The heuristic fallback picks the largest
    ``block_h`` whose spatially-tiled working set — halo-overlapped input
    tile, weight block, s32 accumulator + output tile — fits the VMEM
    budget, so VMEM use is bounded by the tile rather than the image and
    large resolutions stay feasible (DESIGN.md §10).
    """
    spec.validate()   # beyond-bound layouts are rejected here, not in-kernel
    backend = resolve_backend(backend)
    _, h, w, cp = x_shape
    fh, fw, cdim, co = w_shape
    if weight_store == "dense" and k_full is None:
        k_full = cp * spec.n_pack
    if padding == "SAME":
        h, w = h + fh - 1, w + fw - 1
    out_h, out_w = h - fh + 1, w - fw + 1
    budget = vmem_budget or int(hw.VMEM_PER_CORE * VMEM_FRACTION)

    def working_set_at(bh, bco):
        return conv2d_working_set(bh, bco, fh=fh, fw=fw, w=w, cp=cp,
                                  cdim=cdim, out_w=out_w, spec=spec,
                                  weight_store=weight_store)

    if use_tuning_cache and block_h is None and block_co is None:
        from repro.kernels import autotune
        entry = _tuned_entry(
            autotune.conv2d_key(tuple(x_shape), tuple(w_shape), spec,
                                padding=padding, backend=backend,
                                weight_store=weight_store),
            budget,
            lambda e: working_set_at(int(e["block_h"]), int(e["block_co"])))
        if entry is not None:
            bh = min(int(entry["block_h"]), out_h)
            bco = min(int(entry["block_co"]), co)
            return KernelPlan(
                op="packed_conv2d", backend=backend, spec=spec,
                interpret=default_interpret(), weight_store=weight_store,
                k_full=k_full, block_h=bh, block_co=bco,
                vmem_bytes=working_set_at(bh, bco), source="tuned")

    bco = block_co or min(8, co)

    def working_set(bh):
        return working_set_at(bh, bco)

    if block_h is None:
        if working_set(out_h) <= budget:
            block_h = out_h            # whole image fits: single tile
        else:
            block_h = 1
            for cand in _CONV_BLOCK_H_CANDIDATES:
                if cand < out_h and working_set(cand) <= budget:
                    block_h = cand
                    break
    block_h = min(block_h, out_h)
    return KernelPlan(
        op="packed_conv2d", backend=backend, spec=spec,
        interpret=default_interpret(), weight_store=weight_store,
        k_full=k_full, block_h=block_h, block_co=bco,
        vmem_bytes=working_set(block_h))


@functools.lru_cache(maxsize=None)
def plan_quantize_pack(m: int, k: int, spec: PackSpec, *,
                       backend: str = "auto",
                       vmem_budget: int | None = None) -> KernelPlan:
    """Plan the fused runtime quantize+pack over [m, k] activations."""
    backend = resolve_backend(backend)
    budget = vmem_budget or int(hw.VMEM_PER_CORE * VMEM_FRACTION)
    bm = 256
    # cap the K tile at the (n_pack-rounded) activation width: a 512 default
    # on a narrow decode layer would quantize mostly padding
    k_rounded = max(spec.n_pack, -(-k // spec.n_pack) * spec.n_pack)
    bk = min(512, k_rounded)

    def working_set(bm, bk):
        # f32 in + s32 lattice + packed lanes + row-sum scratch
        return bm * bk * (4 + 4) + bm * (bk // spec.n_pack) * \
            _lane_bytes(spec) + bm * 4

    while bm > 8 and working_set(bm, bk) > budget:
        bm //= 2
    return KernelPlan(op="quantize_pack", backend=backend, spec=spec,
                      interpret=default_interpret(), block_m=bm, block_k=bk,
                      vmem_bytes=working_set(bm, bk))


@functools.lru_cache(maxsize=None)
def plan_int_matmul(m: int, k: int, n: int, *, backend: str = "auto",
                    vmem_budget: int | None = None) -> KernelPlan:
    """Plan the unpacked integer matmul baseline."""
    backend = resolve_backend(backend)
    budget = vmem_budget or int(hw.VMEM_PER_CORE * VMEM_FRACTION)
    bm, bn, bk = 128, 128, 512

    def working_set(bm, bn, bk):
        return (bm * bk + bk * bn) * 2 + 2 * bm * bn * 4

    while bk > 64 and working_set(bm, bn, bk) > budget:
        bk //= 2
    return KernelPlan(op="int_matmul", backend=backend, spec=None,
                      interpret=default_interpret(), block_m=bm, block_n=bn,
                      block_k=bk, vmem_bytes=working_set(bm, bn, bk))


@functools.lru_cache(maxsize=None)
def plan_attention_decode(b: int, skv: int, h: int, kvh: int, hd: int,
                          kv_bits: int, *, page_size: int | None = None,
                          backend: str = "auto",
                          vmem_budget: int | None = None,
                          use_tuning_cache: bool = True) -> KernelPlan:
    """Plan the fused flash-decoding attention read (DESIGN.md §20).

    ``skv`` is the logical view length (slot extent, or pages x page_size
    for a paged cache); ``page_size`` non-None selects the paged variant.
    Tile fields: ``block_k`` = KV token rows per online-softmax group,
    ``chunks`` = block-table pages walked per group (paged only; always
    ``block_k // page_size``).  The autotune cache is consulted first
    (kernels/autotune.tune_attention_decode); the heuristic picks the
    largest power-of-two group <= 512 rows that fits the VMEM budget —
    groups only amortize the combine epilogue, so smaller is safe.
    """
    backend = resolve_backend(backend)
    groups = max(1, h // kvh)
    budget = vmem_budget or int(hw.VMEM_PER_CORE * VMEM_FRACTION)

    def clamp(bk: int) -> tuple[int, int]:
        """Round a candidate group length to the layout's grain: whole
        pages when paged, <= skv always."""
        if page_size:
            pp = max(1, min(bk // page_size, -(-skv // page_size)))
            return pp * page_size, pp
        return min(max(1, bk), skv), 1

    if use_tuning_cache:
        from repro.kernels import autotune
        entry = _tuned_entry(
            autotune.attention_decode_key(b, skv, h, kvh, hd, kv_bits,
                                          page_size=page_size,
                                          backend=backend),
            budget,
            lambda e: attention_decode_working_set(int(e["block_k"]), kvh,
                                                   hd, groups))
        if entry is not None:
            bk, chunks = clamp(int(entry["block_k"]))
            return KernelPlan(
                op="attention_decode", backend=backend,
                interpret=default_interpret(), block_k=bk, chunks=chunks,
                vmem_bytes=attention_decode_working_set(bk, kvh, hd,
                                                        groups),
                source="tuned")

    bk = 512 if page_size is None else 8 * page_size
    bk, chunks = clamp(bk)
    while bk > (page_size or 1) and \
            attention_decode_working_set(bk, kvh, hd, groups) > budget:
        bk, chunks = clamp(bk // 2)
    return KernelPlan(
        op="attention_decode", backend=backend,
        interpret=default_interpret(), block_k=bk, chunks=chunks,
        vmem_bytes=attention_decode_working_set(bk, kvh, hd, groups))


def clear_plan_cache():
    """Drop all memoized plans (tests / device changes)."""
    plan_packed_matmul.cache_clear()
    plan_packed_conv2d.cache_clear()
    plan_quantize_pack.cache_clear()
    plan_int_matmul.cache_clear()
    plan_attention_decode.cache_clear()

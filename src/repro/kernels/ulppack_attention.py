"""Fused sub-byte decode attention: flash-decoding over the packed KV cache.

The serving decode hot path used to read the KV cache through
``_cache_read`` / ``_paged_cache_read``: dequantize (or gather, for the
paged pool) the ENTIRE allocated view, then run a two-pass softmax over a
full ``[C, Sk]`` score block.  Sub-byte storage pays for itself only while
the packed words stay packed until the compute instruction (the paper's
``vmacsr`` discipline; FullPack/Quark make the same point) — so this module
restructures decode attention as flash-decoding (DESIGN.md §20):

  * the KV length is split into groups (``plan.block_k`` token rows;
    ``plan.chunks`` block-table pages per group when paged) and each group
    is unpacked, dequantized and contracted in registers/VMEM;
  * a running (max, sum, accumulator) carry combines groups — the online
    softmax — so no full score block ever materializes;
  * paged caches are walked group-by-group THROUGH the block table (the
    whole-view ``pool[block_tables]`` gather copy disappears);
  * groups entirely past every row's live length are skipped with a
    ``lax.cond`` — the old path paid O(allocated), this one pays O(live);
  * sub-byte scores fold the midpoint zero-point into the contraction:
    ``s = scale_k * (q . u - zp * sum(q))`` and the value side
    ``out += (p * scale_v) . u - zp * sum(p * scale_v)`` keep the lattice
    integer until the per-group epilogue.

Two registered backends for the ``attention_decode`` op:

  'xla'    — the algorithm above in plain jnp (python-unrolled group loop).
             This is the deployed CPU path and the only GSPMD-partitionable
             one, so kv-head-sharded serving (``kv_shard_axis``) pins it.
  'pallas' — the real kernel: grid (batch, kv-split), online-softmax carry
             in VMEM scratch, shift-mask word unpack in-kernel, and — paged
             — a scalar-prefetched block table whose entries ARE the
             kv-split block indices (``PrefetchScalarGridSpec``), i.e. the
             block-table walk happens in the kernel's index_map.  Runs
             interpreted off-TPU (plan.default_interpret()).

``fused_decode_attention`` is the models/attention.py entry point; the
``REPRO_FUSED_DECODE=0`` environment kill-switch (read at trace time;
launch/steps.py keys its jit memo on it) restores the legacy read path.
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import plan as plan_lib

NEG_INF = -1e30

#: Environment kill-switch: "0" disables the fused decode path everywhere
#: (models/attention.py falls back to the legacy whole-view read).  Read at
#: trace time — launch/steps.py includes :func:`enabled` in its jit memo
#: keys so flipping the flag never hits a stale trace.
ENV_FLAG = "REPRO_FUSED_DECODE"


def enabled() -> bool:
    return os.environ.get(ENV_FLAG, "1") != "0"


@contextlib.contextmanager
def disabled():
    """Context manager: run with the fused decode path off (tests use this
    to produce legacy-path references from the same process)."""
    old = os.environ.get(ENV_FLAG)
    os.environ[ENV_FLAG] = "0"
    try:
        yield
    finally:
        if old is None:
            os.environ.pop(ENV_FLAG, None)
        else:
            os.environ[ENV_FLAG] = old


# ---------------------------------------------------------------------------
# Shared pieces
# ---------------------------------------------------------------------------

def _unpack_group(words, bits, hd):
    """int32 words [..., hdw] -> f32 lattice values [..., hd] (the shift/
    mask expansion of packing.unpack_words, ascending field order)."""
    per = 32 // bits
    mask = (1 << bits) - 1
    shifts = jnp.arange(per, dtype=jnp.int32) * bits
    vals = (words[..., None] >> shifts) & mask          # [..., hdw, per]
    vals = vals.reshape(*words.shape[:-1], words.shape[-1] * per)
    return vals[..., :hd].astype(jnp.float32)


def _prep_q(q, kvh):
    """[B, C, H, hd] -> pre-scaled f32 [B, C, KVH, G, hd] + row sums."""
    b, c, h, hd = q.shape
    qg = (q.astype(jnp.float32) * hd ** -0.5).reshape(b, c, kvh,
                                                      h // kvh, hd)
    return qg, jnp.sum(qg, axis=-1)


def _combine(carry, s, ok, u_v, ssv, zp):
    """One online-softmax step: fold a group's masked scores ``s``
    [B, C, KVH, G, L] and values ``u_v`` [B, L, KVH, hd] into the running
    (max, sum, acc) carry.  ``ssv`` is the group's value-scale plane
    broadcast like ``s`` (None for float caches), ``zp`` the lattice
    midpoint (0 for symmetric/float storage)."""
    m, l, acc = carry
    s = jnp.where(ok, s, NEG_INF)
    mn = jnp.maximum(m, jnp.max(s, axis=-1))
    corr = jnp.exp(m - mn)
    p = jnp.where(ok, jnp.exp(s - mn[..., None]), 0.0)
    l2 = l * corr + jnp.sum(p, axis=-1)
    pv = p if ssv is None else p * ssv
    av = jnp.einsum("bckgs,bskd->bckgd", pv, u_v,
                    preferred_element_type=jnp.float32)
    if zp:
        av = av - (zp * jnp.sum(pv, axis=-1))[..., None]
    return mn, l2, acc * corr[..., None] + av


def _group_scores(qg, qsum, gk, gsk, kv_bits, hd, zp):
    """Scores of one KV group: ``gk`` is the group's stored K ([B, L, KVH,
    hd] float, [B, L, KVH, hd] int8, or [B, L, KVH, hdw] packed words),
    ``gsk`` its scale plane [B, L, KVH] (None for float caches).
    Returns scores [B, C, KVH, G, L]."""
    u = (_unpack_group(gk, kv_bits, hd) if kv_bits in (4, 2)
         else gk.astype(jnp.float32))
    s = jnp.einsum("bckgd,bskd->bckgs", qg, u,
                   preferred_element_type=jnp.float32)
    if gsk is not None:
        ss = gsk.astype(jnp.float32).transpose(0, 2, 1)[:, None, :, None, :]
        s = ss * (s - zp * qsum[..., None] if zp else s)
    return s


def _finish(carry, b, c, h, hd, out_dtype):
    m, l, acc = carry
    out = acc / jnp.where(l == 0, 1.0, l)[..., None]
    return out.reshape(b, c, h, hd).astype(out_dtype)


def _scale_broadcast(gsv):
    if gsv is None:
        return None
    return gsv.astype(jnp.float32).transpose(0, 2, 1)[:, None, :, None, :]


# ---------------------------------------------------------------------------
# 'xla' backend — fused flash-decoding in plain jnp (CPU / sharded serving)
# ---------------------------------------------------------------------------

@plan_lib.register_backend("attention_decode", "xla")
def _attention_decode_xla(plan, q, cache, valid_len, qpos, *, kv_bits, hd,
                          block_tables=None):
    """Python-unrolled group loop; each group guarded by a ``lax.cond`` on
    ``group_start < max(valid_len)`` so fully-dead groups cost one scalar
    compare instead of an unpack + two contractions."""
    b, c, h, _ = q.shape
    kvh = cache["k"].shape[2]
    zp = (1 << (kv_bits - 1)) if kv_bits in (4, 2) else 0
    quantized = "k_scale" in cache
    qg, qsum = _prep_q(q, kvh)
    groups = h // kvh
    carry = (jnp.full((b, c, kvh, groups), NEG_INF, jnp.float32),
             jnp.zeros((b, c, kvh, groups), jnp.float32),
             jnp.zeros((b, c, kvh, groups, hd), jnp.float32))
    live_max = jnp.max(valid_len)

    if block_tables is not None:
        page_rows = cache["k"].shape[1]
        n_pages = block_tables.shape[1]
        pp = max(1, plan.chunks or 1)
        starts = range(0, n_pages, pp)
    else:
        skv = cache["k"].shape[1]
        bk = max(1, plan.block_k or skv)
        starts = range(0, skv, bk)

    for g0 in starts:
        if block_tables is not None:
            t0 = g0 * page_rows

            def read(g0=g0):
                pages = block_tables[:, g0:g0 + pp]
                span = pages.shape[1] * page_rows

                def gather(buf):
                    gg = buf[pages]
                    return gg.reshape(b, span, *gg.shape[3:])
                gk, gv = gather(cache["k"]), gather(cache["v"])
                gsk = gather(cache["k_scale"]) if quantized else None
                gsv = gather(cache["v_scale"]) if quantized else None
                return gk, gv, gsk, gsv, span
        else:
            t0 = g0

            def read(g0=g0):
                sl = slice(g0, g0 + bk)
                gk, gv = cache["k"][:, sl], cache["v"][:, sl]
                gsk = cache["k_scale"][:, sl] if quantized else None
                gsv = cache["v_scale"][:, sl] if quantized else None
                return gk, gv, gsk, gsv, gk.shape[1]

        def body(carry, read=read, t0=t0):
            gk, gv, gsk, gsv, span = read()
            s = _group_scores(qg, qsum, gk, gsk, kv_bits, hd, zp)
            pos = t0 + jnp.arange(span, dtype=jnp.int32)
            ok = ((pos[None, None, :] < valid_len[:, None, None])
                  & (pos[None, None, :] <= qpos[:, :, None]))
            ok = ok[:, :, None, None, :]
            u_v = (_unpack_group(gv, kv_bits, hd) if kv_bits in (4, 2)
                   else gv.astype(jnp.float32))
            return _combine(carry, s, ok, u_v, _scale_broadcast(gsv), zp)

        carry = jax.lax.cond(t0 < live_max, body, lambda cr: cr, carry)

    return _finish(carry, b, c, h, hd, q.dtype)


# ---------------------------------------------------------------------------
# 'pallas' backend — the real kernel (interpreted off-TPU)
# ---------------------------------------------------------------------------

def _decode_kernel(qg_ref, qs_ref, vl_ref, qp_ref, k_ref, v_ref, sk_ref,
                   sv_ref, o_ref, m_ref, l_ref, acc_ref, *, kv_bits, hd,
                   zp, span):
    """Grid (B, n_splits): one batch row x one KV group per program.

    Carry lives in VMEM scratch across the split sweep (same discipline as
    ulppack_matmul's accumulator); split j covers token rows
    ``j*span .. j*span+span`` of the row's logical view — for the paged
    variant the group's pool block was already selected by the
    block-table index_map, so position arithmetic is identical."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qg = qg_ref[0]                                  # [KVH, G, hd] f32
    if kv_bits in (4, 2):
        u_k = _unpack_group(k_ref[0], kv_bits, hd)  # [span, KVH, hd]
        u_v = _unpack_group(v_ref[0], kv_bits, hd)
    else:
        u_k = k_ref[0].astype(jnp.float32)
        u_v = v_ref[0].astype(jnp.float32)
    # batched over KVH: [KVH, G, hd] x [span, KVH, hd] -> [KVH, G, span]
    s = jax.lax.dot_general(qg, u_k, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)
    if sk_ref is not None:
        ssk = sk_ref[0].astype(jnp.float32).T[:, None, :]   # [KVH, 1, span]
        if zp:
            s = ssk * (s - zp * qs_ref[0][..., None])
        else:
            s = ssk * s
    pos = j * span + jnp.arange(span, dtype=jnp.int32)
    ok = ((pos < vl_ref[0, 0]) & (pos <= qp_ref[0, 0]))[None, None, :]
    s = jnp.where(ok, s, NEG_INF)
    m = m_ref[...]
    mn = jnp.maximum(m, jnp.max(s, axis=-1))
    corr = jnp.exp(m - mn)
    p = jnp.where(ok, jnp.exp(s - mn[..., None]), 0.0)
    l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=-1)
    if sv_ref is not None:
        p = p * sv_ref[0].astype(jnp.float32).T[:, None, :]
    # [KVH, G, span] x [span, KVH, hd] -> [KVH, G, hd]
    av = jax.lax.dot_general(p, u_v, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)
    if zp:
        av = av - (zp * jnp.sum(p, axis=-1))[..., None]
    acc_ref[...] = acc_ref[...] * corr[..., None] + av
    m_ref[...] = mn

    @pl.when(j == pl.num_programs(1) - 1)
    def _done():
        ll = l_ref[...]
        o_ref[0] = acc_ref[...] / jnp.where(ll == 0, 1.0, ll)[..., None]


def _pad_tokens(x, multiple):
    rem = (-x.shape[1]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[1] = (0, rem)
    return jnp.pad(x, pad)


@plan_lib.register_backend("attention_decode", "pallas")
def _attention_decode_pallas(plan, q, cache, valid_len, qpos, *, kv_bits,
                             hd, block_tables=None):
    """Pallas flash-decoding kernel; sq == 1 decode only (the dispatcher
    routes wider windows to the 'xla' backend).

    Contiguous: grid (B, ceil(Sk / block_k)), token-sliced BlockSpecs.
    Paged: grid (B, n_pages) under ``PrefetchScalarGridSpec`` — the
    scalar-prefetched block table IS the pool index_map (``bt[i, j]``),
    one page per grid step, so the kernel walks each row's page list
    without ever materializing the gathered view."""
    b, c, h, _ = q.shape
    if c != 1:
        raise ValueError("pallas attention_decode handles sq == 1 only")
    kvh = cache["k"].shape[2]
    groups = h // kvh
    zp = (1 << (kv_bits - 1)) if kv_bits in (4, 2) else 0
    quantized = "k_scale" in cache
    qg, qsum = _prep_q(q, kvh)
    qg = qg[:, 0]                                   # [B, KVH, G, hd]
    qsum = qsum[:, 0]
    vl = valid_len.astype(jnp.int32).reshape(b, 1)
    qp = qpos[:, 0].astype(jnp.int32).reshape(b, 1)
    word_dim = cache["k"].shape[-1]
    scratch = [pltpu.VMEM((kvh, groups), jnp.float32),
               pltpu.VMEM((kvh, groups), jnp.float32),
               pltpu.VMEM((kvh, groups, hd), jnp.float32)]
    out_shape = jax.ShapeDtypeStruct((b, kvh, groups, hd), jnp.float32)

    if block_tables is not None:
        page_rows = cache["k"].shape[1]
        bt = jnp.clip(block_tables.astype(jnp.int32), 0,
                      cache["k"].shape[0] - 1)
        kern = functools.partial(_decode_kernel, kv_bits=kv_bits, hd=hd,
                                 zp=zp, span=page_rows)
        if not quantized:
            kern = functools.partial(_no_scale_kernel, kern)
        # scalar-prefetch operands are handed to the kernel as a leading
        # ref; the index_maps already consumed the table, so drop it here
        kern = functools.partial(_drop_prefetch_ref, kern)
        # index_maps take (i, j, bt_ref): batch-row operands index by i,
        # pool operands by the scalar-prefetched block table — the
        # in-kernel block-table walk.
        in_specs = [
            pl.BlockSpec((1, kvh, groups, hd),
                         lambda i, j, bt_: (i, 0, 0, 0)),
            pl.BlockSpec((1, kvh, groups), lambda i, j, bt_: (i, 0, 0)),
            pl.BlockSpec((1, 1), lambda i, j, bt_: (i, 0)),
            pl.BlockSpec((1, 1), lambda i, j, bt_: (i, 0)),
            pl.BlockSpec((1, page_rows, kvh, word_dim),
                         lambda i, j, bt_: (bt_[i, j], 0, 0, 0)),
            pl.BlockSpec((1, page_rows, kvh, word_dim),
                         lambda i, j, bt_: (bt_[i, j], 0, 0, 0)),
        ]
        args = [qg, qsum, vl, qp, cache["k"], cache["v"]]
        if quantized:
            in_specs += [
                pl.BlockSpec((1, page_rows, kvh),
                             lambda i, j, bt_: (bt_[i, j], 0, 0)),
                pl.BlockSpec((1, page_rows, kvh),
                             lambda i, j, bt_: (bt_[i, j], 0, 0)),
            ]
            args += [cache["k_scale"], cache["v_scale"]]
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, block_tables.shape[1]),
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, kvh, groups, hd),
                                   lambda i, j, bt_: (i, 0, 0, 0)),
            scratch_shapes=scratch)
        out = pl.pallas_call(kern, grid_spec=grid_spec,
                             out_shape=out_shape,
                             interpret=plan.interpret)(bt, *args)
        return out.reshape(b, 1, h, hd).astype(q.dtype)

    skv = cache["k"].shape[1]
    bk = min(max(1, plan.block_k or skv), skv)
    kern = functools.partial(_decode_kernel, kv_bits=kv_bits, hd=hd, zp=zp,
                             span=bk)
    if not quantized:
        kern = functools.partial(_no_scale_kernel, kern)
    in_specs = [
        pl.BlockSpec((1, kvh, groups, hd), lambda i, j: (i, 0, 0, 0)),
        pl.BlockSpec((1, kvh, groups), lambda i, j: (i, 0, 0)),
        pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        pl.BlockSpec((1, bk, kvh, word_dim), lambda i, j: (i, j, 0, 0)),
        pl.BlockSpec((1, bk, kvh, word_dim), lambda i, j: (i, j, 0, 0)),
    ]
    ks = _pad_tokens(cache["k"], bk)
    args = [qg, qsum, vl, qp, ks, _pad_tokens(cache["v"], bk)]
    if quantized:
        in_specs += [
            pl.BlockSpec((1, bk, kvh), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, bk, kvh), lambda i, j: (i, j, 0)),
        ]
        args += [_pad_tokens(cache["k_scale"], bk),
                 _pad_tokens(cache["v_scale"], bk)]
    out = pl.pallas_call(
        kern,
        grid=(b, ks.shape[1] // bk),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, kvh, groups, hd),
                               lambda i, j: (i, 0, 0, 0)),
        out_shape=out_shape,
        scratch_shapes=scratch,
        interpret=plan.interpret,
    )(*args)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def _no_scale_kernel(kern, qg_ref, qs_ref, vl_ref, qp_ref, k_ref, v_ref,
                     o_ref, m_ref, l_ref, acc_ref):
    """Adapter for float (kv_bits 0/16) caches: no scale-plane operands."""
    kern(qg_ref, qs_ref, vl_ref, qp_ref, k_ref, v_ref, None, None, o_ref,
         m_ref, l_ref, acc_ref)


def _drop_prefetch_ref(kern, bt_ref, *refs):
    """Adapter for the paged variant: the scalar-prefetched block table
    arrives as the kernel's leading ref but is only read by index_maps."""
    kern(*refs)


# ---------------------------------------------------------------------------
# Entry point (models/attention.py)
# ---------------------------------------------------------------------------

def fused_decode_attention(q, cache, valid_len, qpos, *, kv_bits, hd,
                           plan=None, block_tables=None, backend="auto"):
    """Flash-decoding attention over the stored (possibly packed) cache.

    q [B, C, H, hd]; ``cache`` the stored layout (init_kv_cache /
    init_paged_kv_cache); ``valid_len`` [B] live token rows per sequence
    (logical-view prefix); ``qpos`` [B, C] absolute query positions.
    ``plan`` defaults to :func:`plan_attention_decode` for the shape;
    the 'pallas' backend serves C == 1 only (wider verify windows route
    to 'xla').  Returns [B, C, H, hd] in q.dtype.
    """
    b, c, h, _ = q.shape
    kvh = cache["k"].shape[2]
    if plan is None:
        page_size = cache["k"].shape[1] if block_tables is not None else None
        skv = (block_tables.shape[1] * cache["k"].shape[1]
               if block_tables is not None else cache["k"].shape[1])
        plan = plan_lib.plan_attention_decode(
            b, skv, h, kvh, hd, kv_bits, page_size=page_size,
            backend=backend)
    if plan.backend == "pallas" and c != 1:
        plan = dataclasses.replace(plan, backend="xla")
    return plan_lib.dispatch(plan, q, cache,
                             jnp.asarray(valid_len, jnp.int32),
                             jnp.asarray(qpos, jnp.int32),
                             kv_bits=kv_bits, hd=hd,
                             block_tables=block_tables)

"""Empirical KernelPlan autotuner with a persisted, schema-versioned cache.

The static planners in kernels/plan.py pick tile sizes from closed-form VMEM
accounting — correct, but shape-agnostic beyond the budget test.  Sparq's
speedups (3.2x at 2-bit, 1.7x at 4-bit over int16) come from matching the
schedule to the hardware's vector geometry per shape, and FullPack makes the
same point for lane layout: sub-byte throughput is won or lost in per-shape
tile selection.  This module is the software analogue — an offline
measurement pass over a *bounded* candidate grid:

  * ``tune_packed_matmul``   — block_m / block_n / chunks
  * ``tune_packed_conv2d``   — block_h / block_co
  * ``tune_attention_chunk`` — q-chunk of the fused-dequant attention loop
  * ``tune_matmul_layout`` / ``tune_conv2d_layout`` — the PackSpec lane
    layout itself (packing.LAYOUT_FAMILY), tiling each candidate via the
    tuners above and verifying bit-exactness vs the unpacked reference

Layout choices are keyed WITHOUT the row count (weights pack once offline
and serve every batch size) and resolved by ``matmul_layout_for`` /
``conv2d_layout_for`` — the one function packers, planners, and dispatch all
call, so the layout the stored bytes use and the layout the kernel expects
can never drift while one cache is active (DESIGN.md §16).

Winners are persisted to a JSON tuning cache (``reports/autotune_<device>.
json``; the CPU cache is committed so CI plans deterministically).  The
planners consult the *active* cache first and fall back to their heuristics
on miss; plans stay frozen/``lru_cache``d, so dispatch cost is unchanged
(DESIGN.md §14).

Cache discipline:
  * schema-versioned — a stale or corrupt file is ignored with a warning,
    never an error (the heuristics always work);
  * keyed by kernel signature: op kind, shapes, PackSpec, weight storage,
    backend — and scoped to one device kind per file;
  * entries record the winner's tiles plus measured ``wall_us`` and the
    heuristic's ``heuristic_us`` so benchmarks can report tuned-vs-heuristic
    without re-measuring.

``measure_us`` is the shared timing primitive (median-of-repeats with a
minimum total measurement time); benchmarks/common.py delegates to it so the
CI perf-regression gate and the tuner agree on methodology.
"""

from __future__ import annotations

import dataclasses
import functools
import json
import os
import time
import warnings
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib
from repro.roofline import hw

# Schema 2: PackSpec key strings grew an explicit shift suffix
# ("W2A2/int16xP2s8") and the cache gained layout_* entries recording the
# winning lane layout per shape.  Schema-1 files are ignored with a warning
# and the planners fall back to heuristics (no migration needed — re-tune).
SCHEMA_VERSION = 2

#: Environment override for the cache file the active cache loads from.
ENV_CACHE = "REPRO_AUTOTUNE_CACHE"

#: Candidate grids (bounded by construction; the budget filter shrinks them
#: further per shape).
MATMUL_BLOCK_M = (16, 32, 64, 128, 256)
MATMUL_BLOCK_N = (32, 64, 128, 256)
MATMUL_CHUNKS = (1, 2, 4, 8, 16)
CONV_BLOCK_CO = (4, 8, 16, 32)
ATTN_CHUNKS = (32, 64, 128, 256, 512)
#: KV token rows per online-softmax group of the fused decode kernel
#: (DESIGN.md §20); paged shapes round each candidate to whole pages.
ATTN_DECODE_SPLITS = (64, 128, 256, 512, 1024)

_REPO_ROOT = Path(__file__).resolve().parents[3]


def device_kind() -> str:
    """The device axis of the cache key space ('cpu' / 'tpu' / 'gpu')."""
    return jax.default_backend()


def default_cache_path(device: str | None = None) -> str:
    """$REPRO_AUTOTUNE_CACHE if set, else reports/autotune_<device>.json
    at the repo root (so tests and benchmarks agree regardless of CWD)."""
    env = os.environ.get(ENV_CACHE)
    if env:
        return env
    return str(_REPO_ROOT / "reports"
               / f"autotune_{device or device_kind()}.json")


# ---------------------------------------------------------------------------
# Cache keys — human-readable, deterministic strings
# ---------------------------------------------------------------------------

def matmul_key(m: int, kp: int, n: int, spec: PackSpec, *, backend: str,
               weight_store: str = "lanes") -> str:
    return (f"packed_matmul|{backend}|m={m}|kp={kp}|n={n}|spec={spec}"
            f"|store={weight_store}")


def conv2d_key(x_shape: tuple, w_shape: tuple, spec: PackSpec, *,
               padding: str, backend: str,
               weight_store: str = "lanes") -> str:
    xs = "x".join(str(d) for d in x_shape)
    ws = "x".join(str(d) for d in w_shape)
    return (f"packed_conv2d|{backend}|x={xs}|w={ws}|pad={padding}"
            f"|spec={spec}|store={weight_store}")


def attention_key(b: int, sq: int, skv: int, h: int, kvh: int, hd: int,
                  kv_bits: int) -> str:
    return (f"attention_chunk|b={b}|sq={sq}|skv={skv}|h={h}|kvh={kvh}"
            f"|hd={hd}|kv_bits={kv_bits}")


def attention_decode_key(b: int, skv: int, h: int, kvh: int, hd: int,
                         kv_bits: int, *, page_size: int | None,
                         backend: str) -> str:
    paged = f"|ps={page_size}" if page_size else ""
    return (f"attention_decode|{backend}|b={b}|skv={skv}|h={h}|kvh={kvh}"
            f"|hd={hd}|kv_bits={kv_bits}{paged}")


def matmul_layout_key(k: int, n: int, w_bits: int, a_bits: int, *,
                      backend: str, weight_store: str = "lanes") -> str:
    """Lane-layout choice for a [*, k] x [k, n] weight.  Deliberately NOT
    keyed on the row count: weights are packed once offline and serve every
    batch size, so one layout must win across m."""
    return (f"layout_matmul|{backend}|k={k}|n={n}|w={w_bits}|a={a_bits}"
            f"|store={weight_store}")


def conv2d_layout_key(x_shape: tuple, w_shape: tuple, w_bits: int,
                      a_bits: int, *, padding: str, backend: str,
                      weight_store: str = "lanes") -> str:
    """Lane-layout choice for a conv2d; shapes are the UNPACKED
    x [N, H, W, Cin] and w [Fh, Fw, Cin, Co] (layout-independent)."""
    xs = "x".join(str(d) for d in x_shape)
    ws = "x".join(str(d) for d in w_shape)
    return (f"layout_conv2d|{backend}|x={xs}|w={ws}|pad={padding}"
            f"|wb={w_bits}|ab={a_bits}|store={weight_store}")


# ---------------------------------------------------------------------------
# TuningCache: load / lookup / store / save
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TuningCache:
    """One device's tuning results: {signature key: winner entry}."""

    device: str
    entries: dict = dataclasses.field(default_factory=dict)
    path: str | None = None

    def lookup(self, key: str) -> dict | None:
        return self.entries.get(key)

    def store(self, key: str, entry: dict) -> None:
        self.entries[key] = entry

    def to_json(self) -> dict:
        return {"schema": SCHEMA_VERSION, "device": self.device,
                "entries": self.entries}

    def save(self, path: str | None = None) -> str:
        path = path or self.path or default_cache_path(self.device)
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")
        self.path = path
        return path

    @classmethod
    def load(cls, path: str) -> "TuningCache | None":
        """Parse a cache file; corrupt or stale-schema files are ignored
        with a warning (the planner heuristics remain the fallback)."""
        if not os.path.exists(path):
            return None
        try:
            with open(path) as f:
                raw = json.load(f)
        except (OSError, json.JSONDecodeError) as e:
            warnings.warn(f"ignoring corrupt autotune cache {path}: {e}",
                          stacklevel=2)
            return None
        if not isinstance(raw, dict) or raw.get("schema") != SCHEMA_VERSION:
            warnings.warn(
                f"ignoring autotune cache {path}: schema "
                f"{raw.get('schema') if isinstance(raw, dict) else '?'} != "
                f"{SCHEMA_VERSION}", stacklevel=2)
            return None
        entries = raw.get("entries")
        if not isinstance(entries, dict):
            warnings.warn(f"ignoring autotune cache {path}: no entries dict",
                          stacklevel=2)
            return None
        return cls(device=raw.get("device", "unknown"), entries=entries,
                   path=path)


# ---------------------------------------------------------------------------
# Active cache (what the planners consult)
# ---------------------------------------------------------------------------

_UNSET = object()
_active: TuningCache | object | None = _UNSET


def active_cache() -> TuningCache:
    """The process-wide cache the planners consult.  Lazily loaded from
    ``default_cache_path()`` on first use; an empty per-device cache when
    no file exists (every lookup then misses -> heuristics)."""
    global _active
    if _active is _UNSET:
        dev = device_kind()
        _active = (TuningCache.load(default_cache_path(dev))
                   or TuningCache(device=dev))
    return _active


def set_active_cache(cache: TuningCache) -> TuningCache:
    """Install a cache and invalidate every memoized plan built under the
    previous one (plans are frozen per process otherwise)."""
    global _active
    _active = cache
    plan_lib.clear_plan_cache()
    attention_chunk_for.cache_clear()
    return cache


def load_cache(path: str) -> TuningCache:
    """Load + activate ``path`` (empty active cache if unreadable)."""
    return set_active_cache(TuningCache.load(path)
                            or TuningCache(device=device_kind()))


def reset_active_cache() -> None:
    """Back to the lazy default (tests; device changes)."""
    global _active
    _active = _UNSET
    plan_lib.clear_plan_cache()
    attention_chunk_for.cache_clear()


def lookup(key: str) -> dict | None:
    """Planner-facing lookup against the active cache (never raises)."""
    try:
        return active_cache().lookup(key)
    except Exception as e:  # a broken cache must never break planning
        warnings.warn(f"autotune lookup failed: {e}", stacklevel=2)
        return None


def _store(cache: TuningCache, key: str, entry: dict) -> None:
    """Store a tuning result; writes to the ACTIVE cache invalidate every
    memoized plan so later planner calls see the new entry."""
    cache.store(key, entry)
    if cache is _active:
        plan_lib.clear_plan_cache()
        attention_chunk_for.cache_clear()


# ---------------------------------------------------------------------------
# Timing: median-of-repeats with a minimum total measurement time
# ---------------------------------------------------------------------------

def measure_us(fn, *args, repeats: int = 3, min_time_s: float = 0.01,
               iters: int = 1, max_calls: int = 256,
               warmup: int = 1) -> float:
    """Median-of-``repeats`` wall time per call, in microseconds.

    Each sample times a batch of calls; the batch size starts at ``iters``
    and doubles until one batch takes at least ``min_time_s`` (capped at
    ``max_calls``), so fast kernels are not measured at timer resolution and
    the CI regression gate does not flake on noisy runners.  The first
    (timed) calibration batch also absorbs any remaining compilation."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))

    def batch(ncalls: int) -> float:
        t0 = time.perf_counter()
        for _ in range(ncalls):
            jax.block_until_ready(fn(*args))
        return time.perf_counter() - t0

    n = max(1, int(iters))
    dt = batch(n)
    while dt < min_time_s and n < max_calls:
        n = min(n * 2, max_calls)
        dt = batch(n)
    samples = [dt / n]
    for _ in range(max(0, repeats - 1)):
        samples.append(batch(n) / n)
    return float(np.median(samples) * 1e6)


# ---------------------------------------------------------------------------
# Candidate grids
# ---------------------------------------------------------------------------

def _pow2_cap(grid, dim: int):
    """Drop grid points whose predecessor already covers ``dim`` (a block
    twice the problem size only adds padding, never a new schedule)."""
    out = []
    for g in grid:
        out.append(g)
        if g >= dim:
            break
    return out


def _bound(cands: list, limit: int) -> list:
    """Deterministically subsample an over-long candidate list."""
    if len(cands) <= limit:
        return cands
    step = len(cands) / limit
    return [cands[int(i * step)] for i in range(limit)]


def matmul_candidates(m: int, kp: int, n: int, spec: PackSpec,
                      budget: int, *, limit: int = 16) -> list[tuple]:
    """(block_m, block_n, chunks) triples under the VMEM budget."""
    cands = []
    for bm in _pow2_cap(MATMUL_BLOCK_M, m):
        for bn in _pow2_cap(MATMUL_BLOCK_N, n):
            for ch in MATMUL_CHUNKS:
                if ch * spec.k_tile > 2 * kp:
                    break
                if plan_lib.matmul_working_set(bm, bn, ch, spec) <= budget:
                    cands.append((bm, bn, ch))
    return _bound(cands, limit)


def conv2d_candidates(out_h: int, co: int, ws_fn, budget: int, *,
                      limit: int = 12) -> list[tuple]:
    """(block_h, block_co) pairs under the VMEM budget; ``ws_fn(bh, bco)``
    is the planner's working-set estimate for the shape being tuned."""
    bhs = sorted({min(b, out_h)
                  for b in plan_lib._CONV_BLOCK_H_CANDIDATES + (out_h,)})
    bcos = sorted({min(b, co) for b in CONV_BLOCK_CO})
    cands = [(bh, bco) for bh in bhs for bco in bcos
             if ws_fn(bh, bco) <= budget]
    return _bound(cands, limit)


# ---------------------------------------------------------------------------
# Tuners (offline: measure candidates, persist the winner)
# ---------------------------------------------------------------------------

def _entry(best: tuple, heuristic_us: float, n_cands: int,
           **tiles) -> dict:
    wall, vmem = best
    e = dict(tiles)
    e.update({"wall_us": round(wall, 2),
              "heuristic_us": round(heuristic_us, 2),
              "vmem_bytes": int(vmem), "candidates": n_cands})
    return e


def tune_packed_matmul(m: int, kp: int, n: int, spec: PackSpec, *,
                       backend: str = "auto", weight_store: str = "lanes",
                       k_full: int | None = None,
                       vmem_budget: int | None = None,
                       cache: TuningCache | None = None,
                       max_candidates: int = 16, repeats: int = 3,
                       force: bool = False, seed: int = 0) -> dict:
    """Benchmark the (block_m, block_n, chunks) grid for one matmul
    signature and store the winner in ``cache`` (active cache default)."""
    from repro.kernels import ops  # registers the backends

    backend = plan_lib.resolve_backend(backend)
    cache = cache if cache is not None else active_cache()
    if weight_store == "dense" and k_full is None:
        k_full = kp * spec.n_pack
    key = matmul_key(m, kp, n, spec, backend=backend,
                     weight_store=weight_store)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    budget = vmem_budget or int(hw.VMEM_PER_CORE * plan_lib.VMEM_FRACTION)
    heur = plan_lib.plan_packed_matmul(
        m, kp, n, spec, backend=backend, weight_store=weight_store,
        k_full=k_full, vmem_budget=vmem_budget, use_tuning_cache=False)

    rng = np.random.default_rng(seed)
    k = k_full if k_full is not None else kp * spec.n_pack
    q_a = jnp.asarray(rng.integers(0, spec.max_a + 1, (m, k)), jnp.int32)
    q_w = jnp.asarray(rng.integers(0, spec.max_w + 1, (k, n)), jnp.int32)
    ap = packing.pack_activations(q_a, spec, axis=-1)
    if weight_store == "dense":
        wp = ops.dense_store_weights(q_w, spec.w_bits)
    else:
        wp = packing.pack_weights(q_w, spec, axis=0)

    cands = matmul_candidates(m, kp, n, spec, budget, limit=max_candidates)
    heur_tiles = (heur.block_m, heur.block_n, heur.chunks)
    if heur_tiles not in cands:
        cands.append(heur_tiles)

    best, heuristic_us = None, None
    for bm, bn, ch in cands:
        ws = plan_lib.matmul_working_set(bm, bn, ch, spec)
        plan = dataclasses.replace(heur, block_m=bm, block_n=bn, chunks=ch,
                                   vmem_bytes=ws, source="tuned")
        us = measure_us(lambda: plan_lib.dispatch(plan, ap, wp),
                        repeats=repeats)
        if (bm, bn, ch) == heur_tiles:
            heuristic_us = us
        if best is None or us < best[0]:
            best = (us, ws, bm, bn, ch)

    us, ws, bm, bn, ch = best
    entry = _entry((us, ws), heuristic_us, len(cands),
                   block_m=bm, block_n=bn, chunks=ch)
    _store(cache, key, entry)
    return entry


def tune_packed_conv2d(x_shape: tuple, w_shape: tuple, spec: PackSpec, *,
                       padding: str = "SAME", backend: str = "auto",
                       weight_store: str = "lanes",
                       k_full: int | None = None,
                       vmem_budget: int | None = None,
                       cache: TuningCache | None = None,
                       max_candidates: int = 12, repeats: int = 3,
                       force: bool = False, seed: int = 0) -> dict:
    """Benchmark the (block_h, block_co) grid for one conv2d signature."""
    from repro.kernels import ops

    backend = plan_lib.resolve_backend(backend)
    cache = cache if cache is not None else active_cache()
    nb, h, w, cp = x_shape
    fh, fw, cdim, co = w_shape
    if weight_store == "dense" and k_full is None:
        k_full = cp * spec.n_pack
    key = conv2d_key(tuple(x_shape), tuple(w_shape), spec, padding=padding,
                     backend=backend, weight_store=weight_store)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    budget = vmem_budget or int(hw.VMEM_PER_CORE * plan_lib.VMEM_FRACTION)
    heur = plan_lib.plan_packed_conv2d(
        tuple(x_shape), tuple(w_shape), spec, padding=padding,
        backend=backend, weight_store=weight_store, k_full=k_full,
        vmem_budget=vmem_budget, use_tuning_cache=False)

    rng = np.random.default_rng(seed)
    cin = k_full if k_full is not None else cp * spec.n_pack
    q_x = jnp.asarray(rng.integers(0, spec.max_a + 1, (nb, h, w, cin)),
                      jnp.int32)
    q_w = jnp.asarray(rng.integers(0, spec.max_w + 1, (fh, fw, cin, co)),
                      jnp.int32)
    xp = packing.pack_activations(q_x, spec, axis=-1)
    if weight_store == "dense":
        wp = ops.dense_store_conv_weights(q_w, spec.w_bits)
    else:
        wp = packing.pack_weights(q_w, spec, axis=2)

    ph, pw = (h + fh - 1, w + fw - 1) if padding == "SAME" else (h, w)
    out_h, out_w = ph - fh + 1, pw - fw + 1

    def ws_fn(bh, bco):
        return plan_lib.conv2d_working_set(
            bh, bco, fh=fh, fw=fw, w=pw, cp=cp, cdim=cdim, out_w=out_w,
            spec=spec, weight_store=weight_store)

    cands = conv2d_candidates(out_h, co, ws_fn, budget,
                              limit=max_candidates)
    heur_tiles = (heur.block_h, heur.block_co)
    if heur_tiles not in cands:
        cands.append(heur_tiles)

    best, heuristic_us = None, None
    for bh, bco in cands:
        ws = ws_fn(bh, bco)
        plan = dataclasses.replace(heur, block_h=bh, block_co=bco,
                                   vmem_bytes=ws, source="tuned")
        us = measure_us(lambda: plan_lib.dispatch(plan, xp, wp, padding),
                        repeats=repeats)
        if (bh, bco) == heur_tiles:
            heuristic_us = us
        if best is None or us < best[0]:
            best = (us, ws, bh, bco)

    us, ws, bh, bco = best
    entry = _entry((us, ws), heuristic_us, len(cands),
                   block_h=bh, block_co=bco)
    _store(cache, key, entry)
    return entry


# ---------------------------------------------------------------------------
# Lane-layout sweep: PackSpec as a tuning axis (FullPack-style selection)
# ---------------------------------------------------------------------------

def tune_matmul_layout(m: int, k: int, n: int, base_spec: PackSpec, *,
                       backend: str = "auto", weight_store: str = "lanes",
                       vmem_budget: int | None = None,
                       cache: TuningCache | None = None,
                       max_candidates: int = 16, repeats: int = 3,
                       force: bool = False, seed: int = 0) -> dict:
    """Sweep packing.LAYOUT_FAMILY for one [m, k] x [k, n] matmul.

    Each candidate layout is tile-tuned via :func:`tune_packed_matmul` (so
    the winning layout also lands with tuned tiles) and verified bit-exact
    against the unpacked integer reference before it may win; a layout that
    ever mismatched would silently corrupt every layer packed under it.
    The winner is recorded under :func:`matmul_layout_key` — keyed on
    (k, n), not m — and resolved by :func:`matmul_layout_for`.
    """
    from repro.kernels import ops, ref  # registers the backends

    backend = plan_lib.resolve_backend(backend)
    cache = cache if cache is not None else active_cache()
    key = matmul_layout_key(k, n, base_spec.w_bits, base_spec.a_bits,
                            backend=backend, weight_store=weight_store)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit

    rng = np.random.default_rng(seed)
    q_a = jnp.asarray(rng.integers(0, base_spec.max_a + 1, (m, k)),
                      jnp.int32)
    q_w = jnp.asarray(rng.integers(0, base_spec.max_w + 1, (k, n)),
                      jnp.int32)
    want = np.asarray(ref.matmul_i32_ref(q_a, q_w))

    best, base_us, tried = None, None, 0
    for spec in packing.layout_family(base_spec.w_bits, base_spec.a_bits,
                                      base_spec):
        kp = -(-k // spec.n_pack)
        k_full = k if weight_store == "dense" else None
        entry = tune_packed_matmul(
            m, kp, n, spec, backend=backend, weight_store=weight_store,
            k_full=k_full, vmem_budget=vmem_budget, cache=cache,
            max_candidates=max_candidates, repeats=repeats, force=force,
            seed=seed)
        # Mandatory: the layout must reproduce the unpacked reference
        # bit-for-bit through the tuned plan before it can be selected.
        ap = packing.pack_activations(q_a, spec, axis=-1)
        if weight_store == "dense":
            wp = ops.dense_store_weights(q_w, spec.w_bits)
        else:
            wp = packing.pack_weights(q_w, spec, axis=0)
        got = np.asarray(ops.packed_matmul(
            ap, wp, spec, backend=backend, weight_store=weight_store,
            k_full=k_full))
        if not np.array_equal(got, want):
            warnings.warn(f"layout candidate {spec} failed bit-exactness "
                          f"at m={m} k={k} n={n}; excluded", stacklevel=2)
            continue
        tried += 1
        us = float(entry["wall_us"])
        if spec == base_spec:
            base_us = us
        if best is None or us < best[0]:
            best = (us, spec)

    us, spec = best
    layout_entry = {"spec": str(spec), "wall_us": round(us, 2),
                    "base_spec": str(base_spec),
                    "base_us": (round(base_us, 2) if base_us is not None
                                else None),
                    "candidates": tried}
    _store(cache, key, layout_entry)
    return layout_entry


def tune_conv2d_layout(x_shape: tuple, w_shape: tuple,
                       base_spec: PackSpec, *, padding: str = "SAME",
                       backend: str = "auto", weight_store: str = "lanes",
                       vmem_budget: int | None = None,
                       cache: TuningCache | None = None,
                       max_candidates: int = 12, repeats: int = 3,
                       force: bool = False, seed: int = 0) -> dict:
    """Layout sweep for one conv2d; ``x_shape``/``w_shape`` are the UNPACKED
    x [N, H, W, Cin] and w [Fh, Fw, Cin, Co] (see tune_matmul_layout)."""
    from repro.kernels import ops, ref

    backend = plan_lib.resolve_backend(backend)
    cache = cache if cache is not None else active_cache()
    nb, h, w, cin = x_shape
    fh, fw, _, co = w_shape
    key = conv2d_layout_key(tuple(x_shape), tuple(w_shape),
                            base_spec.w_bits, base_spec.a_bits,
                            padding=padding, backend=backend,
                            weight_store=weight_store)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit

    rng = np.random.default_rng(seed)
    q_x = jnp.asarray(rng.integers(0, base_spec.max_a + 1, (nb, h, w, cin)),
                      jnp.int32)
    q_w = jnp.asarray(rng.integers(0, base_spec.max_w + 1,
                                   (fh, fw, cin, co)), jnp.int32)
    want = np.asarray(ref.conv2d_i32_ref(q_x, q_w, padding=padding))

    best, base_us, tried = None, None, 0
    for spec in packing.layout_family(base_spec.w_bits, base_spec.a_bits,
                                      base_spec):
        cp = -(-cin // spec.n_pack)
        if weight_store == "dense":
            cdim = -(-cin // (32 // spec.w_bits))
            k_full = cin
        else:
            cdim, k_full = cp, None
        entry = tune_packed_conv2d(
            (nb, h, w, cp), (fh, fw, cdim, co), spec, padding=padding,
            backend=backend, weight_store=weight_store, k_full=k_full,
            vmem_budget=vmem_budget, cache=cache,
            max_candidates=max_candidates, repeats=repeats, force=force,
            seed=seed)
        xp = packing.pack_activations(q_x, spec, axis=-1)
        if weight_store == "dense":
            wp = ops.dense_store_conv_weights(q_w, spec.w_bits)
        else:
            wp = packing.pack_weights(q_w, spec, axis=2)
        got = np.asarray(ops.packed_conv2d(
            xp, wp, spec, padding=padding, backend=backend,
            weight_store=weight_store, k_full=k_full))
        if not np.array_equal(got, want):
            warnings.warn(f"layout candidate {spec} failed bit-exactness "
                          f"at x={x_shape} w={w_shape}; excluded",
                          stacklevel=2)
            continue
        tried += 1
        us = float(entry["wall_us"])
        if spec == base_spec:
            base_us = us
        if best is None or us < best[0]:
            best = (us, spec)

    us, spec = best
    layout_entry = {"spec": str(spec), "wall_us": round(us, 2),
                    "base_spec": str(base_spec),
                    "base_us": (round(base_us, 2) if base_us is not None
                                else None),
                    "candidates": tried}
    _store(cache, key, layout_entry)
    return layout_entry


def _layout_from_entry(entry: dict | None, w_bits: int,
                       a_bits: int) -> PackSpec | None:
    """Decode + sanity-check a layout entry; None on any mismatch (the
    caller then falls back to the config-derived spec)."""
    if not isinstance(entry, dict) or not isinstance(entry.get("spec"), str):
        return None
    try:
        spec = PackSpec.parse(entry["spec"])
    except ValueError:
        return None
    if spec.w_bits != w_bits or spec.a_bits != a_bits or not spec.feasible:
        return None
    return spec


def matmul_layout_for(k: int, n: int, base_spec: PackSpec, *,
                      backend: str = "auto",
                      weight_store: str = "lanes") -> PackSpec:
    """The per-layer *chosen* lane layout for a [*, k] x [k, n] weight.

    Packers (serve/prepare, models/common), planners (serve layer plans) and
    dispatch (dense_apply) all resolve through here against the active
    cache, defaulting to the config-derived ``base_spec`` on miss — an empty
    cache reproduces the fixed-layout behavior exactly.
    """
    backend = plan_lib.resolve_backend(backend)
    entry = lookup(matmul_layout_key(k, n, base_spec.w_bits,
                                     base_spec.a_bits, backend=backend,
                                     weight_store=weight_store))
    return _layout_from_entry(entry, base_spec.w_bits,
                              base_spec.a_bits) or base_spec


def conv2d_layout_for(x_shape: tuple, w_shape: tuple,
                      base_spec: PackSpec, *, padding: str = "SAME",
                      backend: str = "auto",
                      weight_store: str = "lanes") -> PackSpec:
    """Chosen lane layout for a conv2d (unpacked shapes; see
    matmul_layout_for)."""
    backend = plan_lib.resolve_backend(backend)
    entry = lookup(conv2d_layout_key(tuple(x_shape), tuple(w_shape),
                                     base_spec.w_bits, base_spec.a_bits,
                                     padding=padding, backend=backend,
                                     weight_store=weight_store))
    return _layout_from_entry(entry, base_spec.w_bits,
                              base_spec.a_bits) or base_spec


def tune_attention_chunk(b: int, sq: int, skv: int, h: int, kvh: int,
                         hd: int, *, kv_bits: int = 0,
                         cache: TuningCache | None = None,
                         repeats: int = 3, force: bool = False,
                         seed: int = 0) -> dict:
    """Benchmark the q-chunk of the fused-dequant attention loop for one
    (batch, q-len, kv-len, heads, head-dim, kv_bits) signature."""
    from repro.models import attention as attn

    cache = cache if cache is not None else active_cache()
    key = attention_key(b, sq, skv, h, kvh, hd, kv_bits)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(b, sq, h, hd)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, hd)), jnp.float32)
    if kv_bits in (8, 4, 2):
        qk, sk = attn._kv_quantize(k, kv_bits)
        qv, sv = attn._kv_quantize(v, kv_bits)

        def kv_fn():
            return (attn._kv_dequantize(qk, sk, jnp.float32, kv_bits, hd),
                    attn._kv_dequantize(qv, sv, jnp.float32, kv_bits, hd))
    else:
        def kv_fn():
            return k, v
    kv_pos = jnp.arange(skv)
    q_pos = jnp.broadcast_to(jnp.arange(sq)[None, :], (b, sq))

    def mask_fn(qpos):
        return kv_pos[None, None, :] <= qpos[:, :, None]

    best, heuristic_us = None, None
    cands = [c for c in ATTN_CHUNKS if c <= max(sq, ATTN_CHUNKS[0])]
    default = 512
    if default not in cands:
        cands.append(default)
    for chunk in cands:
        fn = jax.jit(lambda q, c=chunk: attn._chunked_attention(
            q, kv_fn, mask_fn, q_pos, c))
        us = measure_us(fn, q, repeats=repeats)
        if chunk == default:
            heuristic_us = us
        if best is None or us < best[0]:
            best = (us, chunk)
    us, chunk = best
    entry = {"q_chunk": int(chunk), "wall_us": round(us, 2),
             "heuristic_us": round(heuristic_us, 2),
             "candidates": len(cands)}
    _store(cache, key, entry)
    return entry


@functools.lru_cache(maxsize=None)
def attention_chunk_for(b: int, sq: int, skv: int, h: int, kvh: int,
                        hd: int, kv_bits: int = 0,
                        default: int = 512) -> int:
    """Tuned q-chunk for a fused-attention signature (``default`` on miss).
    Consulted at trace time by models/attention.attention_apply."""
    entry = lookup(attention_key(b, sq, skv, h, kvh, hd, kv_bits))
    if entry and isinstance(entry.get("q_chunk"), int):
        return entry["q_chunk"]
    return default


def attention_decode_candidates(skv: int, page_size: int | None,
                                kvh: int, hd: int, groups: int,
                                budget: int) -> list[int]:
    """block_k candidates (KV rows per group) under the VMEM budget;
    paged shapes are rounded to whole pages and deduped."""
    cands = []
    for bk in _pow2_cap(ATTN_DECODE_SPLITS, skv):
        if page_size:
            bk = max(1, min(bk // page_size, -(-skv // page_size))) \
                * page_size
        bk = min(bk, skv)
        if bk in cands:
            continue
        if plan_lib.attention_decode_working_set(bk, kvh, hd,
                                                 groups) <= budget:
            cands.append(bk)
    return cands or [min(page_size or skv, skv)]


def tune_attention_decode(b: int, skv: int, h: int, kvh: int, hd: int, *,
                          kv_bits: int = 0, page_size: int | None = None,
                          backend: str = "auto",
                          vmem_budget: int | None = None,
                          cache: TuningCache | None = None,
                          repeats: int = 3, force: bool = False,
                          seed: int = 0) -> dict:
    """Benchmark the kv-split grid of the fused flash-decoding attention
    (kernels/ulppack_attention.py, DESIGN.md §20) for one decode signature
    and persist the winner.

    The synthetic workload matches the serving decode shape: sq == 1
    queries against a ``skv``-row stored cache (paged: a pool of
    ``skv / page_size`` pages behind an identity block table) with every
    row ~2/3 live — the dead-split skip is part of what the grid trades
    off, so candidates must see some dead tail.
    """
    from repro.kernels import ulppack_attention  # registers the backends
    from repro.models import attention as attn

    backend = plan_lib.resolve_backend(backend)
    cache = cache if cache is not None else active_cache()
    key = attention_decode_key(b, skv, h, kvh, hd, kv_bits,
                               page_size=page_size, backend=backend)
    if not force:
        hit = cache.lookup(key)
        if hit is not None:
            return hit
    budget = vmem_budget or int(hw.VMEM_PER_CORE * plan_lib.VMEM_FRACTION)
    groups = max(1, h // kvh)
    heur = plan_lib.plan_attention_decode(
        b, skv, h, kvh, hd, kv_bits, page_size=page_size, backend=backend,
        vmem_budget=vmem_budget, use_tuning_cache=False)

    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(b, skv, kvh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, kvh, hd)), jnp.float32)
    if kv_bits in (8, 4, 2):
        qk, sk = attn._kv_quantize(k, kv_bits)
        qv, sv = attn._kv_quantize(v, kv_bits)
        kv = {"k": qk, "v": qv, "k_scale": sk, "v_scale": sv}
    else:
        kv = {"k": k, "v": v}
    bt = None
    if page_size:
        n_pages = skv // page_size
        kv = {name: buf.reshape(b * n_pages, page_size, *buf.shape[2:])
              for name, buf in kv.items()}
        bt = jnp.asarray(np.arange(b * n_pages).reshape(b, n_pages),
                         jnp.int32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    live = max(1, (2 * skv) // 3)
    valid_len = jnp.full((b,), live, jnp.int32)
    qpos = jnp.full((b, 1), live - 1, jnp.int32)

    cands = attention_decode_candidates(skv, page_size, kvh, hd, groups,
                                        budget)
    if heur.block_k not in cands:
        cands.append(heur.block_k)

    best, heuristic_us = None, None
    for bk in cands:
        chunks = max(1, bk // page_size) if page_size else 1
        ws = plan_lib.attention_decode_working_set(bk, kvh, hd, groups)
        plan = dataclasses.replace(heur, block_k=bk, chunks=chunks,
                                   vmem_bytes=ws, source="tuned")
        fn = jax.jit(functools.partial(
            ulppack_attention.fused_decode_attention, kv_bits=kv_bits,
            hd=hd, plan=plan, block_tables=bt))
        us = measure_us(fn, q, kv, valid_len, qpos, repeats=repeats)
        if bk == heur.block_k:
            heuristic_us = us
        if best is None or us < best[0]:
            best = (us, ws, bk, chunks)

    us, ws, bk, chunks = best
    entry = _entry((us, ws), heuristic_us, len(cands),
                   block_k=bk, chunks=chunks)
    _store(cache, key, entry)
    return entry

"""Fused runtime quantize+pack Pallas kernel.

The paper measures activation packing *at runtime* as part of conv2d cost
(§V-A).  On TPU we fuse quantization (affine lattice), P1 packing and the
zero-point row-sum reduction into a single VMEM pass so the packed operand is
produced in one read of the activation tensor.  Emits:
  packed  [M, K/n_pack]  lane dtype
  row_sum [M, 1]         s32   (sum_k q_a — for the affine correction)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib


def _kernel(x_ref, s_ref, z_ref, packed_ref, rs_ref, rs_acc,
            *, spec: PackSpec):
    @pl.when(pl.program_id(1) == 0)
    def _init():
        rs_acc[...] = jnp.zeros_like(rs_acc)

    x = x_ref[...].astype(jnp.float32)
    scale = s_ref[0, 0]
    zp = z_ref[0, 0]
    qmax = (1 << spec.a_bits) - 1
    q = jnp.clip(jnp.round(x / scale) + zp, 0, qmax).astype(jnp.int32)
    bm, bk = q.shape
    qr = q.reshape(bm, bk // spec.n_pack, spec.n_pack)
    packed = jnp.zeros(qr.shape[:2], jnp.int32)
    for j in range(spec.n_pack):
        packed = packed + (qr[..., j] << (spec.shift * j))
    packed_ref[...] = packed.astype(spec.lane_dtype)
    rs_acc[...] += jnp.sum(q, axis=1, keepdims=True)

    @pl.when(pl.program_id(1) == pl.num_programs(1) - 1)
    def _done():
        rs_ref[...] = rs_acc[...]


def _pad_axis(x, axis, multiple):
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit, static_argnames=("spec", "block_m", "block_k", "interpret"))
def quantize_pack(x: jax.Array, scale: jax.Array, zero_point: jax.Array,
                  spec: PackSpec, *, block_m: int = 256, block_k: int = 512,
                  interpret: bool | None = None):
    """Quantize to the a_bits lattice and P1-pack along the last axis.

    ``interpret`` defaults from plan.default_interpret(): interpreter on CPU
    (validation mode), compiled on TPU.
    """
    if interpret is None:
        interpret = plan_lib.default_interpret()
    m, k = x.shape
    block_k = max(spec.n_pack, block_k - block_k % spec.n_pack)
    x_p = _pad_axis(_pad_axis(x, 0, block_m), 1, block_k)
    # NOTE: padding rows/cols quantize to q = clip(round(0/s)+zp) = zp, which
    # would corrupt row sums for padded COLUMNS of real rows -> mask them by
    # padding with the dequantized zero so q == zp... instead we pad x with
    # scale*(-zp) so q == 0 exactly.
    if x_p.shape != (m, k):
        fill = -scale * zero_point.astype(jnp.float32)
        mask = jnp.zeros(x_p.shape, bool).at[:m, :k].set(True)
        x_p = jnp.where(mask, x_p, fill)
    s = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    z = jnp.asarray(zero_point, jnp.int32).reshape(1, 1)
    gm = x_p.shape[0] // block_m
    gk = x_p.shape[1] // block_k
    kp_block = block_k // spec.n_pack

    packed, row_sum = pl.pallas_call(
        functools.partial(_kernel, spec=spec),
        grid=(gm, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, kk: (i, kk)),
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0),
                         memory_space=pltpu.SMEM),
            pl.BlockSpec((1, 1), lambda i, kk: (0, 0),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((block_m, kp_block), lambda i, kk: (i, kk)),
            pl.BlockSpec((block_m, 1), lambda i, kk: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((x_p.shape[0], x_p.shape[1] // spec.n_pack),
                                 spec.lane_dtype),
            jax.ShapeDtypeStruct((x_p.shape[0], 1), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((block_m, 1), jnp.int32)],
        interpret=interpret,
    )(x_p, s, z)
    kp = -(-k // spec.n_pack)
    return packed[:m, :kp], row_sum[:m]

"""Fused ULPPACK matmul Pallas TPU kernel — the ``vmacsr`` analogue.

The kernel computes  D[M, N] = sum_k dot-extract(a_packed[M, Kp], w_packed[Kp, N])
where every K-block is processed as ``chunks`` sub-tiles of ``k_tile`` packed
lanes: each sub-tile is one MXU contraction in packed space, immediately
followed by the shift-mask extraction (VPU ops on VMEM-resident registers) and
accumulation into a VMEM s32 accumulator.  This places Sparq's post-multiplier
shifter at the MXU-tile boundary — the TPU-idiomatic fusion point (DESIGN.md
§2) — and keeps the packed partials out of HBM entirely, unlike the native
XLA path (packing.packed_matmul_reference) which round-trips an s32 partial
per k_tile lanes.

Block layout (output-stationary, matching the paper's Algorithm 1):
  grid = (M/bm, N/bn, Kp/bk), k innermost; acc[bm, bn] s32 lives in VMEM
  scratch across the k sweep; bk = chunks * k_tile lanes.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib


def _kernel(a_ref, w_ref, o_ref, acc_ref, *, spec: PackSpec, chunks: int,
            k_tile: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a = a_ref[...]                       # [bm, bk] lane dtype
    w = w_ref[...]                       # [bk, bn] lane dtype
    bm, bk = a.shape
    bn = w.shape[1]
    # [bm, chunks, k_tile] x [chunks, k_tile, bn] -> [chunks, bm, bn] packed
    # totals, one batched MXU contraction per K-block.
    a3 = a.reshape(bm, chunks, k_tile)
    w3 = w.reshape(chunks, k_tile, bn)
    totals = jax.lax.dot_general(
        a3, w3, (((2,), (1,)), ((1,), (0,))),
        preferred_element_type=jnp.int32)
    # vmacsr epilogue: shift to the D band, mask, accumulate wide.
    band = spec.shift * (spec.n_pack - 1)
    d = (totals >> band) & spec.field_mask
    acc_ref[...] += jnp.sum(d, axis=0)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


def _pad_axis(x, axis, multiple):
    rem = (-x.shape[axis]) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(
    jax.jit,
    static_argnames=("spec", "block_m", "block_n", "chunks", "interpret"))
def ulppack_matmul(a_packed: jax.Array, w_packed: jax.Array, spec: PackSpec,
                   *, block_m: int = 128, block_n: int = 128,
                   chunks: int = 8,
                   interpret: bool | None = None) -> jax.Array:
    """Packed-lane matmul: [M, Kp] x [Kp, N] -> s32 [M, N] exact dot values.

    ``interpret`` defaults from plan.default_interpret(): interpreter on CPU
    (validation mode), compiled on TPU.
    VMEM working set per step ~= bm*bk + bk*bn lanes + (chunks+1)*bm*bn s32;
    defaults stay under 2 MiB for int16 lanes with chunks<=8.
    """
    if interpret is None:
        interpret = plan_lib.default_interpret()
    if not spec.feasible:
        raise ValueError(f"{spec} outside the overflow-free region")
    if a_packed.dtype != spec.lane_dtype or w_packed.dtype != spec.lane_dtype:
        raise TypeError("operands must already be packed to spec.lane_dtype")
    m, kp = a_packed.shape
    kp2, n = w_packed.shape
    assert kp == kp2, (kp, kp2)
    k_tile = spec.k_tile
    block_k = chunks * k_tile

    a_p = _pad_axis(_pad_axis(a_packed, 0, block_m), 1, block_k)
    w_p = _pad_axis(_pad_axis(w_packed, 0, block_k), 1, block_n)
    gm = a_p.shape[0] // block_m
    gk = a_p.shape[1] // block_k
    gn = w_p.shape[1] // block_n

    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, chunks=chunks, k_tile=k_tile),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_n), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], w_p.shape[1]),
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p)
    return out[:m, :n]


def _int_kernel(a_ref, w_ref, o_ref, acc_ref):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], w_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(pl.program_id(2) == pl.num_programs(2) - 1)
    def _done():
        o_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("block_m", "block_n", "block_k", "interpret"))
def int_matmul(q_a: jax.Array, q_w: jax.Array, *, block_m: int = 128,
               block_n: int = 128, block_k: int = 512,
               interpret: bool | None = None) -> jax.Array:
    """Unpacked integer matmul kernel (s8/s16 -> s32).

    Baseline kernel: the paper's int16 conv2d counterpart and the W8A8 / out-
    of-region fallback path on TPU.
    """
    if interpret is None:
        interpret = plan_lib.default_interpret()
    m, k = q_a.shape
    _, n = q_w.shape
    a_p = _pad_axis(_pad_axis(q_a, 0, block_m), 1, block_k)
    w_p = _pad_axis(_pad_axis(q_w, 0, block_k), 1, block_n)
    out = pl.pallas_call(
        _int_kernel,
        grid=(a_p.shape[0] // block_m, w_p.shape[1] // block_n,
              a_p.shape[1] // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((a_p.shape[0], w_p.shape[1]),
                                       jnp.int32),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), jnp.int32)],
        interpret=interpret,
    )(a_p, w_p)
    return out[:m, :n]

"""Pure-jnp oracles for every Pallas kernel in this package.

These are the ground truth for correctness tests (exact integer equality for
the packed paths) and the reference FLOP baseline for benchmarks.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.packing import PackSpec


def matmul_i32_ref(q_a: jax.Array, q_w: jax.Array) -> jax.Array:
    """Exact integer matmul oracle: [M, K] x [K, N] -> s32."""
    return jax.lax.dot_general(
        q_a.astype(jnp.int32), q_w.astype(jnp.int32),
        (((q_a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)


def packed_matmul_ref(q_a: jax.Array, q_w: jax.Array, spec: PackSpec):
    """Native-ULPPACK XLA path (pack + tile + extract); bit-exact target."""
    return packing.packed_matmul_reference(q_a, q_w, spec)


def conv2d_i32_ref(q_x: jax.Array, q_w: jax.Array, padding="VALID"):
    """Exact integer conv2d oracle.

    q_x: [N, H, W, C] lattice, q_w: [Fh, Fw, C, Cout] lattice -> s32 NHWC.
    """
    return jax.lax.conv_general_dilated(
        q_x.astype(jnp.int32), q_w.astype(jnp.int32),
        window_strides=(1, 1), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        preferred_element_type=jnp.int32)


def quantize_pack_ref(x: jax.Array, scale, zero_point, spec: PackSpec):
    """Oracle for the fused quantize+pack kernel.

    Returns (packed lanes along last axis, per-row lattice sums for the
    zero-point correction).
    """
    from repro.core import quant
    q = quant.quantize_affine(x, scale, zero_point, spec.a_bits)
    packed = packing.pack_activations(q, spec, axis=-1)
    row_sums = jnp.sum(q, axis=-1).astype(jnp.int32)
    return packed, row_sums


def quantized_linear_ref(x, w, a_scale, a_zp, w_scale, w_zp, a_bits, w_bits):
    """Float oracle of a fully affine-corrected quantized linear layer.

    Quantizes x and w to their lattices, runs the exact integer matmul and
    applies the affine correction (DESIGN.md §4).  The packed kernel path must
    match this to float tolerance (and its integer core exactly).
    """
    from repro.core import quant
    q_a = quant.quantize_affine(x, a_scale, a_zp, a_bits)
    q_w = quant.quantize_affine(w, w_scale, w_zp, w_bits)
    k = x.shape[-1]
    acc = matmul_i32_ref(q_a, q_w).astype(jnp.float32)
    a_sums = jnp.sum(q_a, axis=-1, keepdims=True).astype(jnp.float32)
    w_sums = jnp.sum(q_w, axis=0, keepdims=True).astype(jnp.float32)
    corrected = (acc - w_zp * a_sums - a_zp * w_sums + k * a_zp * w_zp)
    return a_scale * w_scale * corrected

"""Public kernel API: plan-dispatched packed ops + affine-corrected linear.

Every entry point routes through a ``KernelPlan`` (kernels/plan.py): callers
either pass a prebuilt per-layer plan (the deployed path — serve/prepare.py
and models/cnn.py build plans once at preparation time) or a plan is looked
up from the memoized planners on first use for a shape signature.  The
'pallas' and 'xla' implementations of each op are entries in the plan
module's backend registry — there is no ad-hoc backend resolution here.

``backend``:
  'pallas'  — the fused TPU kernels (interpret=True on CPU): the Sparq path.
  'xla'     — pure-XLA packed math (packing.packed_matmul_reference): the
              "native ULPPACK on stock hardware" path, also used inside jitted
              multi-device step functions where a python-gridded interpret
              kernel would be prohibitively slow on CPU.
  'auto'    — pallas on TPU, xla elsewhere (resolved by the planner).

Both backends are bit-exact against kernels/ref.py oracles; tests enforce it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib
from repro.kernels import quant_pack as _quant_pack
from repro.kernels import ulppack_conv2d as _conv
from repro.kernels import ulppack_matmul as _matmul
from repro.kernels.plan import KernelPlan


# ---------------------------------------------------------------------------
# packed_matmul
# ---------------------------------------------------------------------------

def packed_matmul(a_packed, w_packed, spec: PackSpec, *,
                  backend: str = "auto", weight_store: str = "lanes",
                  k_full: int | None = None,
                  plan: KernelPlan | None = None) -> jax.Array:
    """[.., Kp] x [Kp, N] -> exact s32 dot of the underlying lattices.

    With ``weight_store='dense'`` (or a dense plan) ``w_packed`` is bit-dense
    int32 words [ceil(k_full/per), N] and ``k_full`` is the unpacked K.
    """
    lead = a_packed.shape[:-1]
    a2 = a_packed.reshape(-1, a_packed.shape[-1])
    if plan is None:
        plan = plan_lib.plan_packed_matmul(
            a2.shape[0], a2.shape[1], w_packed.shape[-1], spec,
            backend=backend, weight_store=weight_store, k_full=k_full)
    out = plan_lib.dispatch(plan, a2, w_packed)
    return out.reshape(*lead, w_packed.shape[-1])


def _dense_to_lanes(words, spec: PackSpec, k_full: int):
    """Expand bit-dense weight words [Kw, N] -> P1 lanes [Kp, N]."""
    q_w = dense_load_weights(words, spec.w_bits, k_full)
    return packing.pack_weights(q_w, spec, axis=0)


@plan_lib.register_backend("packed_matmul", "pallas")
def _packed_matmul_pallas(plan: KernelPlan, a2, w):
    if plan.weight_store == "dense":
        # Matmul keeps dense expansion at trace level (the HBM weight operand
        # is still the dense words); the conv kernel does it in its prologue.
        w = _dense_to_lanes(w, plan.spec, plan.k_full)
    return _matmul.ulppack_matmul(
        a2, w, plan.spec, block_m=plan.block_m, block_n=plan.block_n,
        chunks=plan.chunks, interpret=plan.interpret)


@plan_lib.register_backend("packed_matmul", "xla")
def _packed_matmul_xla(plan: KernelPlan, a2, w):
    if plan.weight_store == "dense":
        w = _dense_to_lanes(w, plan.spec, plan.k_full)
    return _xla_packed_matmul(a2, w, plan.spec)


def _xla_packed_matmul(a_packed, w_packed, spec: PackSpec,
                       batched_rows: int = 1024):
    """Packed matmul on pre-packed lanes at the XLA level (tiled extraction).

    Two formulations, chosen by row count:
      * rows <= batched_rows (decode/serve): ONE batched dot_general over all
        k-tiles + extraction + tile-sum.  Scan-free, so compiled FLOP counts
        are exact for the roofline analysis (XLA cost analysis does not
        multiply while-loop bodies by trip count).
      * large rows (training-scale fallback): lax.scan over k-tiles — same
        math as packing.packed_matmul_reference.
    """
    kt = spec.k_tile
    a = packing.pad_to_multiple(a_packed, -1, kt)
    w = packing.pad_to_multiple(w_packed, 0, kt)
    n_tiles = a.shape[-1] // kt
    rows = int(np.prod(a_packed.shape[:-1])) if a_packed.ndim > 1 else 1

    if rows <= batched_rows:
        a3 = a.reshape(*a.shape[:-1], n_tiles, kt)        # [.., nc, kt]
        w3 = w.reshape(n_tiles, kt, w.shape[-1])          # [nc, kt, N]
        nd = a3.ndim
        tot = jax.lax.dot_general(
            a3, w3, (((nd - 1,), (1,)), ((nd - 2,), (0,))),
            preferred_element_type=jnp.int32)             # [nc, .., N]
        return jnp.sum(packing.extract_dot(tot, spec), axis=0)

    a_t = jnp.moveaxis(a.reshape(*a.shape[:-1], n_tiles, kt), -2, 0)
    w_t = w.reshape(n_tiles, kt, w.shape[-1])

    def body(carry, xs):
        a_c, w_c = xs
        tot = jax.lax.dot_general(a_c, w_c, (((a_c.ndim - 1,), (0,)),
                                             ((), ())),
                                  preferred_element_type=jnp.int32)
        return carry + packing.extract_dot(tot, spec), None

    init = jnp.zeros((*a_packed.shape[:-1], w_packed.shape[-1]), jnp.int32)
    out, _ = jax.lax.scan(body, init, (a_t, w_t))
    return out


# ---------------------------------------------------------------------------
# quantize_pack
# ---------------------------------------------------------------------------

def quantize_pack(x, scale, zero_point, spec: PackSpec, *,
                  backend: str = "auto", plan: KernelPlan | None = None):
    """Quantize + P1-pack activations along the last axis; also row sums."""
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if plan is None:
        plan = plan_lib.plan_quantize_pack(x2.shape[0], x2.shape[1], spec,
                                           backend=backend)
    packed, rs = plan_lib.dispatch(plan, x2, scale, zero_point)
    kp = packed.shape[-1]
    return packed.reshape(*lead, kp), rs.reshape(*lead, 1)


@plan_lib.register_backend("quantize_pack", "pallas")
def _quantize_pack_pallas(plan: KernelPlan, x2, scale, zero_point):
    return _quant_pack.quantize_pack(
        x2, scale, zero_point, plan.spec, block_m=plan.block_m,
        block_k=plan.block_k, interpret=plan.interpret)


@plan_lib.register_backend("quantize_pack", "xla")
def _quantize_pack_xla(plan: KernelPlan, x2, scale, zero_point):
    from repro.core import quant
    q = quant.quantize_affine(x2, scale, zero_point, plan.spec.a_bits)
    packed = packing.pack_activations(q, plan.spec, axis=-1)
    rs = jnp.sum(q, axis=-1, keepdims=True).astype(jnp.int32)
    return packed, rs


# ---------------------------------------------------------------------------
# packed_conv2d
# ---------------------------------------------------------------------------

def packed_conv2d(x_packed, w_packed, spec: PackSpec, *,
                  padding: str = "SAME", backend: str = "auto",
                  weight_store: str = "lanes", k_full: int | None = None,
                  plan: KernelPlan | None = None):
    """Packed conv2d [N,H,W,Cp] x [Fh,Fw,Cdim,Co] -> s32 NHWC.

    The spatial tiling (block_h) and weight-storage mode come from the plan;
    see kernels/plan.py.  With ``weight_store='dense'`` the weight operand is
    bit-dense words; pass ``k_full`` (= Cin) when it is not a multiple of
    n_pack (the planner's default rounds up, which the zero-padded words
    make equivalent).
    """
    if plan is None:
        plan = plan_lib.plan_packed_conv2d(
            tuple(x_packed.shape), tuple(w_packed.shape), spec,
            padding=padding, backend=backend, weight_store=weight_store,
            k_full=k_full)
    return plan_lib.dispatch(plan, x_packed, w_packed, padding)


@plan_lib.register_backend("packed_conv2d", "pallas")
def _packed_conv2d_pallas(plan: KernelPlan, x_packed, w_packed, padding):
    return _conv.ulppack_conv2d(
        x_packed, w_packed, plan.spec, block_h=plan.block_h,
        block_co=plan.block_co, padding=padding, interpret=plan.interpret,
        weight_store=plan.weight_store, k_full=plan.k_full)


@plan_lib.register_backend("packed_conv2d", "xla")
def _packed_conv2d_xla(plan: KernelPlan, x_packed, w_packed, padding):
    spec = plan.spec
    if plan.weight_store == "dense":
        w_packed = _conv.expand_dense_taps(w_packed, spec, plan.k_full)
    kt = spec.k_tile
    cp = x_packed.shape[-1]
    out = None
    for c0 in range(0, cp, kt):
        c1 = min(c0 + kt, cp)
        tot = jax.lax.conv_general_dilated(
            x_packed[..., c0:c1].astype(jnp.int32),
            w_packed[:, :, c0:c1, :].astype(jnp.int32),
            (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32)
        d = packing.extract_dot(tot, spec)
        out = d if out is None else out + d
    return out


# ---------------------------------------------------------------------------
# int_matmul
# ---------------------------------------------------------------------------

def int_matmul(q_a, q_w, *, backend: str = "auto",
               plan: KernelPlan | None = None):
    lead = q_a.shape[:-1]
    a2 = q_a.reshape(-1, q_a.shape[-1])
    if plan is None:
        plan = plan_lib.plan_int_matmul(a2.shape[0], a2.shape[1],
                                        q_w.shape[-1], backend=backend)
    out = plan_lib.dispatch(plan, a2, q_w)
    return out.reshape(*lead, q_w.shape[-1])


@plan_lib.register_backend("int_matmul", "pallas")
def _int_matmul_pallas(plan: KernelPlan, a2, q_w):
    return _matmul.int_matmul(a2, q_w, block_m=plan.block_m,
                              block_n=plan.block_n, block_k=plan.block_k,
                              interpret=plan.interpret)


@plan_lib.register_backend("int_matmul", "xla")
def _int_matmul_xla(plan: KernelPlan, a2, q_w):
    return jax.lax.dot_general(a2.astype(jnp.int32), q_w.astype(jnp.int32),
                               (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.int32)


# ---------------------------------------------------------------------------
# quantized_linear (the deployed Sparq linear)
# ---------------------------------------------------------------------------

def quantized_linear(x, w_packed, w_col_sums, a_scale, a_zp, w_scale, w_zp,
                     spec: PackSpec, *, bias=None, backend: str = "auto",
                     weight_store: str = "lanes",
                     plan: KernelPlan | None = None, out_dtype=jnp.float32):
    """The deployed Sparq linear: runtime pack + packed matmul + dequant.

    x:          [..., K] float activations
    w_packed:   [Kp, N] offline-packed weight lanes (field-reversed), or
                [Kw, N] bit-dense int32 words under weight_store='dense'
    w_col_sums: [N] s32 offline per-column lattice sums
    Returns float [..., N]  ==  quantized_linear_ref to float tolerance.
    """
    k = x.shape[-1]
    if plan is None:
        rows = int(np.prod(x.shape[:-1])) if x.ndim > 1 else 1
        kp = -(-k // spec.n_pack)
        plan = plan_lib.plan_packed_matmul(
            rows, kp, w_packed.shape[-1], spec, backend=backend,
            weight_store=weight_store,
            k_full=k if weight_store == "dense" else None)
    a_packed, a_sums = quantize_pack(x, a_scale, a_zp, spec,
                                     backend=plan.backend)
    acc = packed_matmul(a_packed, w_packed, spec, plan=plan)
    acc = acc.astype(jnp.float32)
    corr = (acc
            - jnp.asarray(w_zp, jnp.float32) * a_sums.astype(jnp.float32)
            - jnp.asarray(a_zp, jnp.float32)
            * w_col_sums.astype(jnp.float32)[None, :]
            .reshape((1,) * (acc.ndim - 1) + (-1,))
            + (k * jnp.asarray(a_zp, jnp.float32)
               * jnp.asarray(w_zp, jnp.float32)))
    out = (jnp.asarray(a_scale, jnp.float32)
           * jnp.asarray(w_scale, jnp.float32) * corr)
    if bias is not None:
        out = out + bias
    return out.astype(out_dtype)


# ---------------------------------------------------------------------------
# Offline weight preparation
# ---------------------------------------------------------------------------

def prepare_weights(w, w_scale, w_zp, spec: PackSpec, *,
                    weight_store: str = "lanes"):
    """Offline weight path: quantize, pack (field-reversed), column sums.

    ``weight_store='dense'`` stores the lattice bit-dense (int32 words,
    true w_bits/value HBM footprint) instead of as P1 lanes.
    """
    from repro.core import quant
    q_w = quant.quantize_affine(w, w_scale, w_zp, spec.w_bits)
    col_sums = jnp.sum(q_w, axis=0).astype(jnp.int32)
    if weight_store == "dense":
        return dense_store_weights(q_w, spec.w_bits), col_sums
    return packing.pack_weights(q_w, spec, axis=0), col_sums


# ---------------------------------------------------------------------------
# Dense sub-byte weight storage (beyond-paper, §Perf memory-term
# optimization): store w_bits-wide lattice values bit-dense in int32 words
# (true w_bits/value HBM footprint) and expand to P1 lanes at use.  On TPU
# the conv2d expansion lives in the Pallas kernel's VMEM prologue
# (ulppack_conv2d.expand_dense_taps); the matmul / XLA paths materialize the
# lanes at trace level (still saving HBM reads of the weight tensor).
# ---------------------------------------------------------------------------

def dense_store_weights(q_w: jax.Array, w_bits: int) -> jax.Array:
    """[K, N] lattice (< 2^w_bits) -> [ceil(K/per), N] int32 bit-dense."""
    return packing.pack_words(q_w, w_bits, axis=0)


def dense_load_weights(words: jax.Array, w_bits: int, k: int) -> jax.Array:
    """Inverse of dense_store_weights -> [K, N] int32 lattice."""
    return packing.unpack_words(words, w_bits, k, axis=0)


def dense_store_conv_weights(q_w: jax.Array, w_bits: int) -> jax.Array:
    """[Fh, Fw, Cin, Co] lattice -> [Fh, Fw, ceil(Cin/per), Co] int32 words.

    Word-packs the input-channel axis independently per (fh, fw, co) tap, the
    layout ulppack_conv2d's dense prologue expands.
    """
    fh, fw, cin, co = q_w.shape
    flat = q_w.transpose(2, 0, 1, 3).reshape(cin, fh * fw * co)
    words = dense_store_weights(flat, w_bits)
    return words.reshape(-1, fh, fw, co).transpose(1, 2, 0, 3)

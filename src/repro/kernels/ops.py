"""Public kernel API: backend-dispatched packed ops + affine-corrected linear.

``backend``:
  'pallas'  — the fused TPU kernels (interpret=True on CPU): the Sparq path.
  'xla'     — pure-XLA packed math (packing.packed_matmul_reference): the
              "native ULPPACK on stock hardware" path, also used inside jitted
              multi-device step functions where a python-gridded interpret
              kernel would be prohibitively slow on CPU.
  'auto'    — pallas on TPU, xla elsewhere.

Both backends are bit-exact against kernels/ref.py oracles; tests enforce it.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.packing import PackSpec
from repro.kernels import quant_pack as _quant_pack
from repro.kernels import ulppack_conv2d as _conv
from repro.kernels import ulppack_matmul as _matmul


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    return backend


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def packed_matmul(a_packed, w_packed, spec: PackSpec, *,
                  backend: str = "auto") -> jax.Array:
    """[.., Kp] x [Kp, N] -> exact s32 dot of the underlying lattices."""
    backend = _resolve(backend)
    lead = a_packed.shape[:-1]
    a2 = a_packed.reshape(-1, a_packed.shape[-1])
    if backend == "pallas":
        out = _matmul.ulppack_matmul(a2, w_packed, spec,
                                     interpret=_interpret())
    else:
        out = _xla_packed_matmul(a2, w_packed, spec)
    return out.reshape(*lead, w_packed.shape[-1])


def _xla_packed_matmul(a_packed, w_packed, spec: PackSpec,
                       batched_rows: int = 1024):
    """Packed matmul on pre-packed lanes at the XLA level (tiled extraction).

    Two formulations, chosen by row count:
      * rows <= batched_rows (decode/serve): ONE batched dot_general over all
        k-tiles + extraction + tile-sum.  Scan-free, so compiled FLOP counts
        are exact for the roofline analysis (XLA cost analysis does not
        multiply while-loop bodies by trip count).
      * large rows (training-scale fallback): lax.scan over k-tiles — same
        math as packing.packed_matmul_reference.
    """
    kt = spec.k_tile
    a = packing.pad_to_multiple(a_packed, -1, kt)
    w = packing.pad_to_multiple(w_packed, 0, kt)
    n_tiles = a.shape[-1] // kt
    rows = int(np.prod(a_packed.shape[:-1])) if a_packed.ndim > 1 else 1

    if rows <= batched_rows:
        a3 = a.reshape(*a.shape[:-1], n_tiles, kt)        # [.., nc, kt]
        w3 = w.reshape(n_tiles, kt, w.shape[-1])          # [nc, kt, N]
        nd = a3.ndim
        tot = jax.lax.dot_general(
            a3, w3, (((nd - 1,), (1,)), ((nd - 2,), (0,))),
            preferred_element_type=jnp.int32)             # [nc, .., N]
        return jnp.sum(packing.extract_dot(tot, spec), axis=0)

    a_t = jnp.moveaxis(a.reshape(*a.shape[:-1], n_tiles, kt), -2, 0)
    w_t = w.reshape(n_tiles, kt, w.shape[-1])

    def body(carry, xs):
        a_c, w_c = xs
        tot = jax.lax.dot_general(a_c, w_c, (((a_c.ndim - 1,), (0,)),
                                             ((), ())),
                                  preferred_element_type=jnp.int32)
        return carry + packing.extract_dot(tot, spec), None

    init = jnp.zeros((*a_packed.shape[:-1], w_packed.shape[-1]), jnp.int32)
    out, _ = jax.lax.scan(body, init, (a_t, w_t))
    return out


def quantize_pack(x, scale, zero_point, spec: PackSpec, *,
                  backend: str = "auto"):
    """Quantize + P1-pack activations along the last axis; also row sums."""
    backend = _resolve(backend)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, x.shape[-1])
    if backend == "pallas":
        packed, rs = _quant_pack.quantize_pack(x2, scale, zero_point, spec,
                                               interpret=_interpret())
    else:
        from repro.core import quant
        q = quant.quantize_affine(x2, scale, zero_point, spec.a_bits)
        packed = packing.pack_activations(q, spec, axis=-1)
        rs = jnp.sum(q, axis=-1, keepdims=True).astype(jnp.int32)
    kp = packed.shape[-1]
    return packed.reshape(*lead, kp), rs.reshape(*lead, 1)


def packed_conv2d(x_packed, w_packed, spec: PackSpec, *,
                  padding: str = "SAME", backend: str = "auto"):
    backend = _resolve(backend)
    if backend == "pallas":
        return _conv.ulppack_conv2d(x_packed, w_packed, spec,
                                    padding=padding, interpret=_interpret())
    return _xla_packed_conv2d(x_packed, w_packed, spec, padding)


def _xla_packed_conv2d(x_packed, w_packed, spec: PackSpec, padding):
    """XLA packed conv: conv in packed space per k_tile chunk + extraction."""
    kt = spec.k_tile
    cp = x_packed.shape[-1]
    out = None
    for c0 in range(0, cp, kt):
        c1 = min(c0 + kt, cp)
        tot = jax.lax.conv_general_dilated(
            x_packed[..., c0:c1].astype(jnp.int32),
            w_packed[:, :, c0:c1, :].astype(jnp.int32),
            (1, 1), padding, dimension_numbers=("NHWC", "HWIO", "NHWC"),
            preferred_element_type=jnp.int32)
        d = packing.extract_dot(tot, spec)
        out = d if out is None else out + d
    return out


def int_matmul(q_a, q_w, *, backend: str = "auto"):
    backend = _resolve(backend)
    lead = q_a.shape[:-1]
    a2 = q_a.reshape(-1, q_a.shape[-1])
    if backend == "pallas":
        out = _matmul.int_matmul(a2, q_w, interpret=_interpret())
    else:
        out = jax.lax.dot_general(a2.astype(jnp.int32),
                                  q_w.astype(jnp.int32),
                                  (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.int32)
    return out.reshape(*lead, q_w.shape[-1])


def quantized_linear(x, w_packed, w_col_sums, a_scale, a_zp, w_scale, w_zp,
                     spec: PackSpec, *, bias=None, backend: str = "auto",
                     out_dtype=jnp.float32):
    """The deployed Sparq linear: runtime pack + packed matmul + dequant.

    x:          [..., K] float activations
    w_packed:   [Kp, N] offline-packed weight lanes (field-reversed)
    w_col_sums: [N] s32 offline per-column lattice sums
    Returns float [..., N]  ==  quantized_linear_ref to float tolerance.
    """
    k = x.shape[-1]
    a_packed, a_sums = quantize_pack(x, a_scale, a_zp, spec, backend=backend)
    acc = packed_matmul(a_packed, w_packed, spec, backend=backend)
    acc = acc.astype(jnp.float32)
    corr = (acc
            - jnp.asarray(w_zp, jnp.float32) * a_sums.astype(jnp.float32)
            - jnp.asarray(a_zp, jnp.float32)
            * w_col_sums.astype(jnp.float32)[None, :]
            .reshape((1,) * (acc.ndim - 1) + (-1,))
            + (k * jnp.asarray(a_zp, jnp.float32)
               * jnp.asarray(w_zp, jnp.float32)))
    out = (jnp.asarray(a_scale, jnp.float32)
           * jnp.asarray(w_scale, jnp.float32) * corr)
    if bias is not None:
        out = out + bias
    return out.astype(out_dtype)


def prepare_weights(w, w_scale, w_zp, spec: PackSpec):
    """Offline weight path: quantize, pack (field-reversed), column sums."""
    from repro.core import quant
    q_w = quant.quantize_affine(w, w_scale, w_zp, spec.w_bits)
    packed = packing.pack_weights(q_w, spec, axis=0)
    col_sums = jnp.sum(q_w, axis=0).astype(jnp.int32)
    return packed, col_sums


# ---------------------------------------------------------------------------
# Dense sub-byte weight storage (beyond-paper, §Perf memory-term
# optimization): store w_bits-wide lattice values bit-dense in int32 words
# (true w_bits/value HBM footprint) and expand to P1 lanes at use.  On TPU
# the expansion lives in the Pallas kernel's VMEM prologue; the XLA fallback
# materializes the lanes (still saving HBM reads of the weight tensor).
# ---------------------------------------------------------------------------

def dense_store_weights(q_w: jax.Array, w_bits: int) -> jax.Array:
    """[K, N] lattice (< 2^w_bits) -> [ceil(K/per), N] int32 bit-dense."""
    per = 32 // w_bits
    k, n = q_w.shape
    q = packing.pad_to_multiple(q_w.astype(jnp.int32), 0, per)
    q = q.reshape(-1, per, n)
    word = jnp.zeros((q.shape[0], n), jnp.int32)
    for j in range(per):
        word = word | (q[:, j, :] << (w_bits * j))
    return word


def dense_load_weights(words: jax.Array, w_bits: int, k: int) -> jax.Array:
    """Inverse of dense_store_weights -> [K, N] int32 lattice."""
    per = 32 // w_bits
    mask = (1 << w_bits) - 1
    parts = [(words >> (w_bits * j)) & mask for j in range(per)]
    q = jnp.stack(parts, axis=1).reshape(-1, words.shape[-1])
    return q[:k]

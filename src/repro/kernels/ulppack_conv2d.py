"""Packed sub-byte conv2d Pallas kernel (paper §IV-B, Algorithm 1 on TPU).

Output-stationary, channel-packed (ULPPACK P1 over the C axis), with the
``vmacsr`` shift-extract fused after every packed MXU contraction.  The
paper's ``vslidedown`` input reuse becomes VMEM-resident window slicing: each
(fh, fw) kernel tap is a shifted view of the VMEM input tile — no im2col
materialization in HBM, mirroring the paper's motivation for a dedicated conv
algorithm (§III-A).

Spatial tiling (DESIGN.md §10): grid ``(N, out_H/block_h, Co/block_co)``.
Each grid step loads a halo-overlapped input tile of ``block_h + fh - 1`` rows
(``pl.Unblocked`` indexing: consecutive h-tiles advance by ``block_h`` rows
but read ``fh - 1`` shared halo rows), so VMEM use is bounded by the tile —
not the image — and large-resolution inference stays feasible.  ``block_h``
is chosen offline by kernels/plan.py against the VMEM budget.

Weight storage (``weight_store``):
  'lanes' — w is [Fh, Fw, Cp, Co] P1 lanes (field-reversed), the default.
  'dense' — w is [Fh, Fw, ceil(Cin/per), Co] bit-dense int32 words
            (per = 32 // w_bits); the kernel prologue expands words ->
            P1 lanes in VMEM, so HBM only ever holds w_bits per weight.

Layouts: input NHWC (C packed -> Cp lanes), output NHWC s32.  Padding is
applied by the wrapper ('VALID' inside the kernel).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.packing import PackSpec
from repro.kernels import plan as plan_lib


def expand_dense_taps(words: jax.Array, spec: PackSpec,
                      cin: int) -> jax.Array:
    """Bit-dense conv words [Fh, Fw, ceil(cin/per), Co] -> P1 lanes.

    The inverse of ops.dense_store_conv_weights followed by P1 packing, as
    pure shift/mask/reshape VPU ops so it can run inside a kernel prologue.
    Returns [Fh, Fw, cp, Co] lanes with cp = ceil(cin / n_pack).
    """
    per = 32 // spec.w_bits
    mask = (1 << spec.w_bits) - 1
    fh, fw, cwords, co = words.shape
    parts = [(words >> (spec.w_bits * j)) & mask for j in range(per)]
    lat = jnp.stack(parts, axis=3).reshape(fh, fw, cwords * per, co)
    cp = -(-cin // spec.n_pack)
    # dense_store pads cin -> cwords*per with zero lattice values, and
    # cwords*per >= cp*n_pack always (per >= n_pack), so this slice is the
    # zero-padded lattice pack_weights would have produced.
    lat = lat[:, :, :cp * spec.n_pack, :].reshape(fh, fw, cp, spec.n_pack, co)
    lanes = jnp.zeros((fh, fw, cp, co), jnp.int32)
    for j in range(spec.n_pack):
        lanes = lanes + (lat[:, :, :, j, :]
                         << (spec.shift * (spec.n_pack - 1 - j)))
    return lanes.astype(spec.lane_dtype)


def _kernel(x_ref, w_ref, o_ref, *scratch, spec: PackSpec, fh: int, fw: int,
            block_h: int, out_w: int, weight_store: str, k_full: int | None):
    cp = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    kt = spec.k_tile
    band = spec.shift * (spec.n_pack - 1)
    if weight_store == "dense":
        # the co-block is the OUTERMOST grid dim, so the expanded lanes in
        # scratch stay valid across the whole (N, h-tile) inner sweep —
        # words are widened once per weight block, not once per grid step
        lanes_ref, = scratch
        @pl.when((pl.program_id(1) == 0) & (pl.program_id(2) == 0))
        def _expand():
            lanes_ref[...] = expand_dense_taps(w_ref[...], spec, k_full)
        wt = lanes_ref[...]
    else:
        wt = w_ref[...]
    acc = jnp.zeros((block_h * out_w, bco), jnp.int32)
    x = x_ref[0]                                   # [block_h+fh-1, W, Cp]
    for ih in range(fh):
        for iw in range(fw):
            window = jax.lax.slice(
                x, (ih, iw, 0), (ih + block_h, iw + out_w, cp))
            rows = window.reshape(block_h * out_w, cp)
            for c0 in range(0, cp, kt):
                c1 = min(c0 + kt, cp)
                t = jax.lax.dot_general(
                    rows[:, c0:c1], wt[ih, iw, c0:c1, :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc = acc + ((t >> band) & spec.field_mask)
    o_ref[...] = acc.reshape(1, block_h, out_w, bco)


def _int_kernel(x_ref, w_ref, o_ref, *, fh: int, fw: int, block_h: int,
                out_w: int):
    cin = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    acc = jnp.zeros((block_h * out_w, bco), jnp.int32)
    x = x_ref[0]
    for ih in range(fh):
        for iw in range(fw):
            window = jax.lax.slice(
                x, (ih, iw, 0), (ih + block_h, iw + out_w, cin))
            rows = window.reshape(block_h * out_w, cin)
            acc = acc + jax.lax.dot_general(
                rows, w_ref[ih, iw], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    o_ref[...] = acc.reshape(1, block_h, out_w, bco)


def _maybe_pad_spatial(q_x, fh, fw, padding):
    if padding == "VALID":
        return q_x
    if padding == "SAME":
        ph, pw = fh - 1, fw - 1
        return jnp.pad(q_x, ((0, 0), (ph // 2, ph - ph // 2),
                             (pw // 2, pw - pw // 2), (0, 0)))
    raise ValueError(padding)


def _tiled_conv_call(kernel, x, w, *, fh, fw, block_h, block_co, out_h,
                     out_w, interpret, scratch_shapes=()):
    """Shared spatially-tiled pallas_call: halo-overlapped input h-tiles.

    ``block_h`` must already be resolved (the wrappers clamp it once and pass
    the same value here and into the kernel closure).  Grid order is
    (Co-block, N, h-tile): the weight block is outermost so per-block kernel
    prologue work (dense expansion scratch) amortizes over the inner sweep."""
    n, h, wd, cdim = x.shape
    assert 1 <= block_h <= out_h, (block_h, out_h)
    n_bh = -(-out_h // block_h)
    co = w.shape[-1]
    rem = (-co) % block_co
    if rem:
        w = jnp.pad(w, ((0, 0),) * 3 + ((0, rem),))
    gco = w.shape[-1] // block_co
    # Bottom-pad rows so every halo'd tile slice [hb*bh, hb*bh + bh+fh-1) is
    # in-bounds (tail tiles compute rows that are sliced off below).
    need_h = n_bh * block_h + fh - 1
    if need_h > h:
        x = jnp.pad(x, ((0, 0), (0, need_h - h), (0, 0), (0, 0)))

    out = pl.pallas_call(
        kernel,
        grid=(gco, n, n_bh),
        in_specs=[
            pl.BlockSpec((1, block_h + fh - 1, wd, cdim),
                         lambda j, i, hb, bh=block_h: (i, hb * bh, 0, 0),
                         indexing_mode=pl.Unblocked()),
            pl.BlockSpec((fh, fw, w.shape[2], block_co),
                         lambda j, i, hb: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_h, out_w, block_co),
                               lambda j, i, hb: (i, hb, 0, j)),
        out_shape=jax.ShapeDtypeStruct(
            (n, n_bh * block_h, out_w, w.shape[-1]), jnp.int32),
        scratch_shapes=list(scratch_shapes),
        interpret=interpret,
    )(x, w)
    return out[:, :out_h, :, :co]


@functools.partial(
    jax.jit, static_argnames=("spec", "block_h", "block_co", "padding",
                              "interpret", "weight_store", "k_full"))
def ulppack_conv2d(x_packed: jax.Array, w_packed: jax.Array, spec: PackSpec,
                   *, block_h: int | None = None, block_co: int = 8,
                   padding: str = "VALID", interpret: bool | None = None,
                   weight_store: str = "lanes",
                   k_full: int | None = None) -> jax.Array:
    """Packed conv2d: [N,H,W,Cp] x [Fh,Fw,Cp,Co] -> s32 [N,Ho,Wo,Co].

    ``block_h=None`` keeps the whole output height in one tile (the legacy
    full-slab schedule); planners pass a VMEM-budgeted value.  With
    ``weight_store='dense'`` the weight operand is bit-dense int32 words
    [Fh, Fw, ceil(k_full/per), Co] and ``k_full`` (= Cin) is required.
    """
    if interpret is None:
        interpret = plan_lib.default_interpret()
    if not spec.feasible:
        raise ValueError(f"{spec} outside the overflow-free region")
    _, _, _, cp = x_packed.shape
    fh, fw, cdim, _ = w_packed.shape
    if weight_store == "lanes":
        assert cp == cdim, (cp, cdim)
    elif weight_store == "dense":
        if k_full is None:
            raise ValueError("weight_store='dense' requires k_full (Cin)")
        per = 32 // spec.w_bits
        assert cdim == -(-k_full // per), (cdim, k_full, per)
        assert cp == -(-k_full // spec.n_pack), (cp, k_full)
    else:
        raise ValueError(weight_store)
    x_packed = _maybe_pad_spatial(x_packed, fh, fw, padding)
    h, w = x_packed.shape[1], x_packed.shape[2]
    out_h, out_w = h - fh + 1, w - fw + 1
    bh = min(block_h or out_h, out_h)
    scratch = ()
    if weight_store == "dense":
        scratch = (pltpu.VMEM((fh, fw, cp, block_co), spec.lane_dtype),)
    return _tiled_conv_call(
        functools.partial(_kernel, spec=spec, fh=fh, fw=fw, block_h=bh,
                          out_w=out_w, weight_store=weight_store,
                          k_full=k_full),
        x_packed, w_packed, fh=fh, fw=fw, block_h=bh,
        block_co=block_co, out_h=out_h, out_w=out_w, interpret=interpret,
        scratch_shapes=scratch)


@functools.partial(
    jax.jit, static_argnames=("block_h", "block_co", "padding", "interpret"))
def int_conv2d(q_x: jax.Array, q_w: jax.Array, *, block_h: int | None = None,
               block_co: int = 8, padding: str = "VALID",
               interpret: bool | None = None) -> jax.Array:
    """Unpacked integer conv2d kernel (the paper's int16 baseline)."""
    if interpret is None:
        interpret = plan_lib.default_interpret()
    fh, fw, _, _ = q_w.shape
    q_x = _maybe_pad_spatial(q_x, fh, fw, padding)
    h, w = q_x.shape[1], q_x.shape[2]
    out_h, out_w = h - fh + 1, w - fw + 1
    bh = min(block_h or out_h, out_h)
    return _tiled_conv_call(
        functools.partial(_int_kernel, fh=fh, fw=fw, block_h=bh,
                          out_w=out_w),
        q_x, q_w, fh=fh, fw=fw, block_h=bh, block_co=block_co,
        out_h=out_h, out_w=out_w, interpret=interpret)

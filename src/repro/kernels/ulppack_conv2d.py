"""Packed sub-byte conv2d Pallas kernel (paper §IV-B, Algorithm 1 on TPU).

Output-stationary, channel-packed (ULPPACK P1 over the C axis), with the
``vmacsr`` shift-extract fused after every packed MXU contraction.  The
paper's ``vslidedown`` input reuse becomes VMEM-resident window slicing: the
input slab for a batch element stays in VMEM and each (fh, fw) kernel tap is a
shifted view — no im2col materialization in HBM, mirroring the paper's
motivation for a dedicated conv algorithm (§III-A).

Layouts: input NHWC (C packed -> Cp lanes), weights HWIO (I packed, field-
reversed), output NHWC s32.  Padding is applied by the wrapper ('VALID'
inside the kernel).  Grid: (N, Cout/bco); per grid step the full H x W slab is
resident, sized for v5e VMEM at the paper's benchmark shapes (DESIGN.md §10).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.packing import PackSpec


def _kernel(x_ref, w_ref, o_ref, *, spec: PackSpec, fh: int, fw: int,
            out_h: int, out_w: int):
    cp = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    kt = spec.k_tile
    band = spec.shift * (spec.n_pack - 1)
    acc = jnp.zeros((out_h * out_w, bco), jnp.int32)
    x = x_ref[0]                                   # [H, W, Cp]
    for ih in range(fh):
        for iw in range(fw):
            window = jax.lax.slice(
                x, (ih, iw, 0), (ih + out_h, iw + out_w, cp))
            rows = window.reshape(out_h * out_w, cp)
            for c0 in range(0, cp, kt):
                c1 = min(c0 + kt, cp)
                t = jax.lax.dot_general(
                    rows[:, c0:c1], w_ref[ih, iw, c0:c1, :],
                    (((1,), (0,)), ((), ())),
                    preferred_element_type=jnp.int32)
                acc = acc + ((t >> band) & spec.field_mask)
    o_ref[...] = acc.reshape(1, out_h, out_w, bco)


def _int_kernel(x_ref, w_ref, o_ref, *, fh: int, fw: int, out_h: int,
                out_w: int):
    cin = x_ref.shape[-1]
    bco = w_ref.shape[-1]
    acc = jnp.zeros((out_h * out_w, bco), jnp.int32)
    x = x_ref[0]
    for ih in range(fh):
        for iw in range(fw):
            window = jax.lax.slice(
                x, (ih, iw, 0), (ih + out_h, iw + out_w, cin))
            rows = window.reshape(out_h * out_w, cin)
            acc = acc + jax.lax.dot_general(
                rows, w_ref[ih, iw], (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32)
    o_ref[...] = acc.reshape(1, out_h, out_w, bco)


def _maybe_pad_spatial(q_x, fh, fw, padding):
    if padding == "VALID":
        return q_x
    if padding == "SAME":
        ph, pw = fh - 1, fw - 1
        return jnp.pad(q_x, ((0, 0), (ph // 2, ph - ph // 2),
                             (pw // 2, pw - pw // 2), (0, 0)))
    raise ValueError(padding)


@functools.partial(
    jax.jit, static_argnames=("spec", "block_co", "padding", "interpret"))
def ulppack_conv2d(x_packed: jax.Array, w_packed: jax.Array, spec: PackSpec,
                   *, block_co: int = 8, padding: str = "VALID",
                   interpret: bool = True) -> jax.Array:
    """Packed conv2d: [N,H,W,Cp] x [Fh,Fw,Cp,Co] -> s32 [N,Ho,Wo,Co]."""
    if not spec.feasible:
        raise ValueError(f"{spec} outside the overflow-free region")
    n, _, _, cp = x_packed.shape
    fh, fw, cp2, co = w_packed.shape
    assert cp == cp2, (cp, cp2)
    x_packed = _maybe_pad_spatial(x_packed, fh, fw, padding)
    h, w = x_packed.shape[1], x_packed.shape[2]
    out_h, out_w = h - fh + 1, w - fw + 1
    rem = (-co) % block_co
    if rem:
        w_packed = jnp.pad(w_packed, ((0, 0),) * 3 + ((0, rem),))
    gco = w_packed.shape[-1] // block_co

    out = pl.pallas_call(
        functools.partial(_kernel, spec=spec, fh=fh, fw=fw,
                          out_h=out_h, out_w=out_w),
        grid=(n, gco),
        in_specs=[
            pl.BlockSpec((1, h, w, cp), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((fh, fw, cp, block_co), lambda i, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, block_co),
                               lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, out_h, out_w, w_packed.shape[-1]),
                                       jnp.int32),
        interpret=interpret,
    )(x_packed, w_packed)
    return out[..., :co]


@functools.partial(
    jax.jit, static_argnames=("block_co", "padding", "interpret"))
def int_conv2d(q_x: jax.Array, q_w: jax.Array, *, block_co: int = 8,
               padding: str = "VALID", interpret: bool = True) -> jax.Array:
    """Unpacked integer conv2d kernel (the paper's int16 baseline)."""
    n = q_x.shape[0]
    fh, fw, cin, co = q_w.shape
    q_x = _maybe_pad_spatial(q_x, fh, fw, padding)
    h, w = q_x.shape[1], q_x.shape[2]
    out_h, out_w = h - fh + 1, w - fw + 1
    rem = (-co) % block_co
    if rem:
        q_w = jnp.pad(q_w, ((0, 0),) * 3 + ((0, rem),))
    gco = q_w.shape[-1] // block_co
    out = pl.pallas_call(
        functools.partial(_int_kernel, fh=fh, fw=fw, out_h=out_h,
                          out_w=out_w),
        grid=(n, gco),
        in_specs=[
            pl.BlockSpec((1, h, w, cin), lambda i, j: (i, 0, 0, 0)),
            pl.BlockSpec((fh, fw, cin, block_co), lambda i, j: (0, 0, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, out_h, out_w, block_co),
                               lambda i, j: (i, 0, 0, j)),
        out_shape=jax.ShapeDtypeStruct((n, out_h, out_w, q_w.shape[-1]),
                                       jnp.int32),
        interpret=interpret,
    )(q_x, q_w)
    return out[..., :co]

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell on the production mesh with 512 placeholder host devices.

THE TWO LINES ABOVE MUST STAY FIRST — jax locks the device count on first
init, so the flag must be set before any other import (including repro.*).

Single-cell mode (used by the orchestrator, one subprocess per cell so a
crash or RAM spike in one compile cannot take down the sweep):

    PYTHONPATH=src python -m repro.launch.dryrun --arch stablelm-1.6b \
        --shape train_4k [--multi-pod] --out reports/dryrun/<cell>.json

Sweep mode:

    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] \
        [--jobs N] [--timeout S]
"""

import argparse
import json
import subprocess
import sys
import time
import traceback
from pathlib import Path

import jax

REPORT_DIR = Path(__file__).resolve().parents[3] / "reports" / "dryrun"


def lower_cell(arch: str, shape_name: str, multi_pod: bool,
               lower_only: bool = False, kv_bits: int = -1) -> dict:
    from repro import configs
    from repro.launch import shapes as shp
    from repro.launch import steps as steps_lib
    from repro.launch.mesh import make_production_mesh
    from repro.models import lm
    from repro.parallel import sharding
    from repro.roofline import analysis
    from repro.serve import prepare

    t0 = time.time()
    live, reason = shp.cell_is_live(arch, shape_name)
    if not live:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "SKIP", "reason": reason}

    cfg = configs.get_config(arch)
    if kv_bits >= 0:
        import dataclasses as _dc
        cfg = cfg.replace(quant=_dc.replace(cfg.quant, kv_bits=kv_bits))
    shape = shp.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = len(mesh.devices.reshape(-1))
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)

    from repro.parallel.sharding import activation_mesh
    with mesh, activation_mesh(mesh):
        if shape.kind == "train":
            state_struct = jax.eval_shape(
                lambda: steps_lib.make_train_state(
                    lm.init_params(jax.random.PRNGKey(0), cfg), cfg=cfg))
            batch_struct = shp.input_specs(cfg, shape_name)
            p_sh = sharding.param_shardings(state_struct["params"], cfg,
                                            mesh)
            o_sh = sharding.opt_state_shardings(state_struct["opt_state"],
                                                p_sh, cfg, mesh)
            st_sh = {"params": p_sh, "opt_state": o_sh,
                     "step": jax.sharding.NamedSharding(
                         mesh, jax.sharding.PartitionSpec())}
            b_sh = sharding.batch_shardings(batch_struct, cfg, mesh,
                                            shape.global_batch)
            step = steps_lib.make_train_step(cfg)
            jitted = jax.jit(step, in_shardings=(st_sh, b_sh),
                             donate_argnums=(0,))
            lowered = jitted.lower(state_struct, batch_struct)
        elif shape.kind == "prefill":
            params_struct = jax.eval_shape(
                lambda: lm.init_params(jax.random.PRNGKey(0), cfg))
            batch_struct = shp.input_specs(cfg, shape_name)
            p_sh = sharding.param_shardings(params_struct, cfg, mesh)
            b_sh = sharding.batch_shardings(batch_struct, cfg, mesh,
                                            shape.global_batch)
            step = steps_lib.make_prefill_step(cfg, shape.seq_len)
            jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
            lowered = jitted.lower(params_struct, batch_struct)
        else:  # decode
            params_struct = jax.eval_shape(
                lambda: prepare.prepare_serving_params(
                    lm.init_params(jax.random.PRNGKey(0), cfg), cfg))
            specs = shp.input_specs(cfg, shape_name)
            caches_struct, batch_struct = specs["caches"], specs["batch"]
            p_sh = sharding.param_shardings(params_struct, cfg, mesh)
            c_sh = sharding.cache_shardings(
                caches_struct, cfg, mesh, shape.global_batch,
                sequence_parallel=(shape_name == "long_500k"))
            b_sh = sharding.batch_shardings(batch_struct, cfg, mesh,
                                            shape.global_batch)
            i_sh = jax.sharding.NamedSharding(mesh,
                                              jax.sharding.PartitionSpec())
            step = steps_lib.make_decode_step(cfg)
            jitted = jax.jit(step, in_shardings=(p_sh, c_sh, b_sh, i_sh),
                             donate_argnums=(1,))
            lowered = jitted.lower(params_struct, caches_struct,
                                   batch_struct, specs["index"])

        t_lower = time.time() - t0
        if lower_only:
            return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                    "status": "LOWER_OK", "lower_s": round(t_lower, 1)}
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        mem = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        hlo = compiled.as_text()
        coll = analysis.collective_bytes(hlo)
        mflops = analysis.model_flops(cfg, shape)

    report = analysis.summarize_cell(arch, shape_name, mesh_name, chips,
                                     cost or {}, coll, mflops)
    report.update({
        "status": "OK",
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory_analysis": _mem_dict(mem),
        "param_count_total": cfg.param_counts()["total"],
        "param_count_active": cfg.param_counts()["active"],
    })
    return report


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    out = {}
    for k in ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes",
              "alias_size_in_bytes"):
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    return out


def run_single(args):
    try:
        report = lower_cell(args.arch, args.shape, args.multi_pod,
                            lower_only=args.lower_only,
                            kv_bits=args.kv_bits)
    except Exception as e:  # structured failure for the sweep report
        report = {"arch": args.arch, "shape": args.shape,
                  "mesh": "2x16x16" if args.multi_pod else "16x16",
                  "status": "FAIL", "error": f"{type(e).__name__}: {e}",
                  "traceback": traceback.format_exc()[-4000:]}
    out = json.dumps(report, indent=1, default=str)
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(out)
    print(out)
    if report["status"] in ("OK", "LOWER_OK"):
        print(f"\n[dry-run OK] {args.arch} x {args.shape} "
              f"mesh={report['mesh']} dominant={report.get('dominant')}")
    return 0 if report["status"] in ("OK", "SKIP", "LOWER_OK") else 1


def run_all(args):
    from repro import configs
    from repro.launch import shapes as shp

    REPORT_DIR.mkdir(parents=True, exist_ok=True)
    cells = [(a, s) for a in configs.ARCH_NAMES for s in shp.SHAPES]
    meshes = [True, False] if args.multi_pod_also else [args.multi_pod]
    jobs = []
    for mp in meshes:
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'mp' if mp else 'sp'}"
            out = REPORT_DIR / f"{tag}.json"
            if out.exists() and not args.force:
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out", str(out)]
            if mp:
                cmd.append("--multi-pod")
            jobs.append((tag, cmd))

    running, failed, done = [], [], 0
    while jobs or running:
        while jobs and len(running) < args.jobs:
            tag, cmd = jobs.pop(0)
            p = subprocess.Popen(cmd, stdout=subprocess.DEVNULL,
                                 stderr=subprocess.PIPE)
            running.append((tag, p, time.time()))
            print(f"[start] {tag} ({len(jobs)} queued)")
        still = []
        for tag, p, t0 in running:
            rc = p.poll()
            if rc is None:
                if time.time() - t0 > args.timeout:
                    p.kill()
                    failed.append((tag, "timeout"))
                    print(f"[TIMEOUT] {tag}")
                else:
                    still.append((tag, p, t0))
            else:
                done += 1
                if rc != 0:
                    err = p.stderr.read().decode()[-500:]
                    failed.append((tag, err))
                    print(f"[FAIL rc={rc}] {tag}")
                else:
                    print(f"[done {time.time()-t0:.0f}s] {tag}")
        running = still
        time.sleep(2)
    print(f"\ncompleted={done} failed={len(failed)}")
    for tag, err in failed:
        print(f"  FAILED {tag}: {err[:200]}")
    return 1 if failed else 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--multi-pod-also", action="store_true",
                    help="sweep both meshes (with --all)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--jobs", type=int, default=4)
    ap.add_argument("--timeout", type=float, default=3600)
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--lower-only", action="store_true")
    ap.add_argument("--kv-bits", type=int, default=-1,
                    help="override cfg.quant.kv_bits (hillclimb knob)")
    ap.add_argument("--out")
    args = ap.parse_args()
    if args.all:
        sys.exit(run_all(args))
    sys.exit(run_single(args))


if __name__ == "__main__":
    main()

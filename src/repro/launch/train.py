"""CLI trainer: --arch <id> [--reduced] with the fault-tolerant loop.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --reduced --steps 100 --ckpt-dir /tmp/ckpt

Full configs on real hardware would add --mesh data,model sizing; on this CPU
container the reduced configs exercise the identical code path end-to-end.
"""

from __future__ import annotations

import argparse

from repro import configs
from repro.data.pipeline import DataConfig
from repro.train.loop import TrainLoopConfig, Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--schedule", default=None,
                    help="cosine|wsd (default: wsd for minicpm, else cosine)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    if cfg.family == "cnn":
        raise SystemExit("use examples/train_cnn_qat.py for sparq-cnn")
    schedule = args.schedule or (
        "wsd" if args.arch == "minicpm-2b" else "cosine")
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch)
    loop = TrainLoopConfig(total_steps=args.steps,
                           checkpoint_every=args.ckpt_every,
                           checkpoint_dir=args.ckpt_dir)
    trainer = Trainer(cfg, loop, data_cfg,
                      train_step_kwargs={"peak_lr": args.lr,
                                         "schedule": schedule,
                                         "total_steps": args.steps})
    trainer.install_preemption_handler()
    trainer.run()


if __name__ == "__main__":
    main()

"""Production mesh definition (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (tests / examples)."""
    n = len(jax.devices())
    data = min(data, n)
    model = min(model, max(1, n // data))
    return jax.make_mesh((data, model), ("data", "model"))

"""Production mesh definition (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (tests / examples).

    Both axes of the ``(data, model)`` request are validated (>= 1) and
    infeasible requests are clamped to what the host actually has —
    loudly: sharding tests that silently ran on a 1x1 mesh were passing
    without testing anything.
    """
    if data < 1 or model < 1:
        raise ValueError(
            f"mesh axes must be >= 1, got (data={data}, model={model})")
    n = len(jax.devices())
    data_actual = min(data, n)
    model_actual = min(model, max(1, n // data_actual))
    if (data_actual, model_actual) != (data, model):
        warnings.warn(
            f"make_host_mesh: requested (data={data}, model={model}) "
            f"needs {data * model} devices but the host has {n}; "
            f"clamping to (data={data_actual}, model={model_actual}). "
            f"Force more CPU devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N.",
            stacklevel=2)
    return jax.make_mesh((data_actual, model_actual), ("data", "model"))


def make_serving_mesh(model: int = 1, data: int = 1):
    """Serving mesh: ('data', 'model') — a real 2-D request (DESIGN.md §17).

    ``model`` is the tensor-parallel width of one replica (replicated
    small batch, sharded packed weights + kv-head-sharded caches —
    serve/shard.py; the ``--model-parallel`` CLI knob); ``data`` is the
    replica-fleet axis: serve/router.Router carves the mesh into ``data``
    replica groups of ``model`` devices each (``replica_meshes``) and
    load-balances requests across them (the ``--data-parallel`` knob).
    Requests beyond the host's device count clamp with the same warning
    as make_host_mesh.  Testable on CPU via
    XLA_FLAGS=--xla_force_host_platform_device_count=8 for (data=2,
    model=2) and beyond.
    """
    if model < 1:
        raise ValueError(f"model parallelism must be >= 1, got {model}")
    if data < 1:
        raise ValueError(f"data parallelism must be >= 1, got {data}")
    return make_host_mesh(data=data, model=model)


def replica_meshes(mesh):
    """Carve a ('data', 'model') mesh into per-replica (1, model) groups.

    Each replica group is a standalone Mesh over one data-row's devices —
    the serving engine's ShardPlan (tensor-parallel over 'model') applies
    to it unchanged, and placing a replica's params/caches onto its group
    is what makes the fleet data-parallel: replicas own disjoint devices.
    """
    if tuple(mesh.axis_names) != ("data", "model"):
        raise ValueError(
            f"expected a ('data', 'model') serving mesh, got axes "
            f"{tuple(mesh.axis_names)}")
    dev = mesh.devices
    return [jax.sharding.Mesh(dev[i:i + 1], ("data", "model"))
            for i in range(dev.shape[0])]

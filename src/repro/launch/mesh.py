"""Production mesh definition (assignment-mandated shapes).

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (the dry-run must set XLA_FLAGS before first jax init).
"""

from __future__ import annotations

import warnings

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips/pod; multi-pod adds a leading 2-pod axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over the real host devices (tests / examples).

    Infeasible ``(data, model)`` requests are clamped to what the host
    actually has — loudly: sharding tests that silently ran on a 1x1 mesh
    were passing without testing anything.
    """
    n = len(jax.devices())
    data_actual = min(data, n)
    model_actual = min(model, max(1, n // data_actual))
    if (data_actual, model_actual) != (data, model):
        warnings.warn(
            f"make_host_mesh: requested (data={data}, model={model}) "
            f"needs {data * model} devices but the host has {n}; "
            f"clamping to (data={data_actual}, model={model_actual}). "
            f"Force more CPU devices with "
            f"XLA_FLAGS=--xla_force_host_platform_device_count=N.",
            stacklevel=2)
    return jax.make_mesh((data_actual, model_actual), ("data", "model"))


def make_serving_mesh(model: int = 1):
    """Serving mesh: ('data', 'model') with data pinned to 1.

    The serving engine is tensor-parallel only (replicated small batch,
    sharded packed weights + kv-head-sharded caches — serve/shard.py);
    ``model`` is the ``--model-parallel`` CLI knob.  Requests beyond the
    host's device count clamp with the same warning as make_host_mesh.
    Testable on CPU via XLA_FLAGS=--xla_force_host_platform_device_count=4.
    """
    if model < 1:
        raise ValueError(f"model parallelism must be >= 1, got {model}")
    return make_host_mesh(data=1, model=model)

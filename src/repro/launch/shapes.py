"""Assigned input shapes x architecture applicability + ShapeDtypeStruct
stand-ins for every model input (no device allocation — dry-run safe).

Shapes (assignment):
  train_4k     seq=4096    global_batch=256   -> train_step
  prefill_32k  seq=32768   global_batch=32    -> serve prefill
  decode_32k   seq=32768   global_batch=128   -> serve decode (1 new token,
                                                 KV cache of seq_len)
  long_500k    seq=524288  global_batch=1     -> decode; sub-quadratic archs
                                                 only (DESIGN.md §5)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import lm


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# Architectures with sub-quadratic decode paths (SSM / hybrid / SWA).
LONG_CONTEXT_ARCHS = {"xlstm-1.3b", "jamba-1.5-large-398b",
                      "mixtral-8x22b", "mixtral-8x7b"}


def cell_is_live(arch_name: str, shape_name: str):
    """(live, reason-if-skipped) for one (arch x shape) cell."""
    if shape_name == "long_500k" and arch_name not in LONG_CONTEXT_ARCHS:
        return False, ("pure full-attention arch: 512k dense-attention "
                       "decode is skipped per assignment (DESIGN.md §5)")
    return True, ""


def _sd(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _vlm_prefix(shape: ShapeSpec) -> int:
    return min(256, shape.seq_len // 4)


def _enc_len(shape: ShapeSpec) -> int:
    return max(8, shape.seq_len // 4)


def train_input_specs(cfg, shape: ShapeSpec):
    gb, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sd((gb, s), jnp.int32),
             "labels": _sd((gb, s), jnp.int32)}
    if cfg.frontend == "vision":
        si = _vlm_prefix(shape)
        batch["tokens"] = _sd((gb, s - si), jnp.int32)
        batch["embeds"] = _sd((gb, si, cfg.frontend_dim), jnp.bfloat16)
        batch["positions"] = _sd((gb, s), jnp.int32)
        batch["positions3"] = _sd((3, gb, s), jnp.int32)
        batch["labels"] = _sd((gb, s), jnp.int32)
    if cfg.frontend == "audio":
        batch["enc_embeds"] = _sd((gb, _enc_len(shape), cfg.frontend_dim),
                                  jnp.bfloat16)
    return batch


def prefill_input_specs(cfg, shape: ShapeSpec):
    gb, s = shape.global_batch, shape.seq_len
    batch = {"tokens": _sd((gb, s), jnp.int32)}
    if cfg.frontend == "vision":
        si = _vlm_prefix(shape)
        batch["tokens"] = _sd((gb, s - si), jnp.int32)
        batch["embeds"] = _sd((gb, si, cfg.frontend_dim), jnp.bfloat16)
        batch["positions"] = _sd((gb, s), jnp.int32)
        batch["positions3"] = _sd((3, gb, s), jnp.int32)
    if cfg.frontend == "audio":
        batch["enc_embeds"] = _sd((gb, _enc_len(shape), cfg.frontend_dim),
                                  jnp.bfloat16)
    return batch


def decode_input_specs(cfg, shape: ShapeSpec):
    """Decode step inputs: one new token + caches sized for seq_len."""
    gb, s = shape.global_batch, shape.seq_len
    caches = jax.eval_shape(
        lambda: lm.init_caches(cfg, gb, s, dtype=jnp.bfloat16))
    if cfg.is_encoder_decoder:
        hd = cfg.resolved_head_dim
        se = _enc_len(shape)
        for c in caches:
            c["cross_kv"] = (
                _sd((gb, se, cfg.num_kv_heads, hd), jnp.bfloat16),
                _sd((gb, se, cfg.num_kv_heads, hd), jnp.bfloat16))
    batch = {"tokens": _sd((gb, 1), jnp.int32)}
    if cfg.mrope:
        batch["positions3"] = _sd((3, gb, 1), jnp.int32)
    return {"batch": batch, "caches": caches,
            "index": _sd((), jnp.int32)}


def input_specs(cfg, shape_name: str):
    shape = SHAPES[shape_name]
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    return decode_input_specs(cfg, shape)

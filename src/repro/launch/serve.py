"""CLI server: pack a model for deployment and serve synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --requests 4
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--no-packed", action="store_true")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    eng = ServingEngine(cfg, params, max_batch=args.max_batch,
                        max_len=args.max_len, packed=not args.no_packed)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, 6).astype(
                np.int32),
            max_new_tokens=args.max_new_tokens))
    t0 = time.time()
    done = eng.run_to_completion()
    dt = time.time() - t0
    toks = sum(len(r.output) for r in done)
    print(f"{len(done)} requests, {toks} tokens, {dt:.1f}s "
          f"({toks/dt:.1f} tok/s)")


if __name__ == "__main__":
    main()

"""CLI server: pack a model for deployment and serve synthetic requests
through the continuous-batching engine — or, with ``--data-parallel N``,
through the replica-fleet Router (serve/router.py, DESIGN.md §17).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --requests 4 --prefill-chunk 16

Tensor-parallel serving (serve/shard.ShardPlan, DESIGN.md §15) on a
CPU-simulated mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --model-parallel 4 --metrics

Replica fleet — a (data=2, model=2) mesh carved into two 2-way-TP
replica groups behind one load-balanced front door:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
        python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --data-parallel 2 --model-parallel 2 --metrics

Flags are grouped (engine / sampling / quantization / parallelism /
fleet) and the engine side is derived through a single
``EngineConfig.from_args`` call, so the CLI and programmatic
construction cannot drift.
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.config import EngineConfig
from repro.serve.engine import Request, ServingEngine


def build_parser() -> argparse.ArgumentParser:
    """The serving CLI surface.  Exposed (not inlined in main) so tests
    can parse flag lists and assert EngineConfig.from_args consistency."""
    ap = argparse.ArgumentParser(
        description="Serve synthetic requests through the packed "
                    "continuous-batching engine or a replica fleet.")
    ap.add_argument("--arch", required=True, choices=configs.ALL_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--metrics", action="store_true",
                    help="print the full metrics report (throughput split "
                         "by phase, occupancy, per-request TTFT and "
                         "time-per-output-token mean/p50/p95; fleet "
                         "aggregate + per-replica under --data-parallel) "
                         "plus the capacity/shard report as JSON")

    eng = ap.add_argument_group(
        "engine", "EngineConfig fields (serve/config.py) — consumed by "
                  "EngineConfig.from_args, the single construction path")
    eng.add_argument("--max-batch", type=int, default=2)
    eng.add_argument("--max-len", type=int, default=64)
    eng.add_argument("--prefill-chunk", type=int, default=16)
    eng.add_argument("--max-queue", type=int, default=0,
                     help="backpressure cap on queued requests per engine "
                          "(0 = none; under a fleet, a full replica queue "
                          "spills to the router)")
    eng.add_argument("--no-packed", action="store_true")
    eng.add_argument("--autotune", action="store_true",
                     help="warm-tune the serving kernel signatures missing "
                          "from the autotune cache before planning, then "
                          "persist the cache (tune once offline; plans "
                          "come back cache-backed on later launches)")
    eng.add_argument("--hbm-cache-budget-mb", type=float, default=0,
                     help="size batch slots from this HBM cache budget "
                          "(slots = budget // cache bytes per slot; with "
                          "--paged-kv, pages = budget // page bytes) "
                          "instead of --max-batch (0 = no budget)")
    eng.add_argument("--paged-kv", action="store_true",
                     help="paged KV cache: block-table indirection over a "
                          "refcounted page pool with prefix sharing and "
                          "copy-on-write (serve/pages.py, DESIGN.md §18); "
                          "the HBM budget then buys pages, --max-batch "
                          "bounds logical slots")
    eng.add_argument("--page-size", type=int, default=16,
                     help="token rows per KV page; must be a multiple of "
                          "the kv-bits word-packing tail (8 for 4-bit, 16 "
                          "for 2-bit)")
    eng.add_argument("--no-prefix-sharing", action="store_true",
                     help="disable radix prefix sharing across paged "
                          "requests (pages still allocated on demand)")
    eng.add_argument("--speculative-k", type=int, default=0,
                     help="speculative decoding (DESIGN.md §19): draft up "
                          "to K tokens per decode pass with a sub-byte "
                          "copy of the model, verify them in one target "
                          "call (0 = off)")
    eng.add_argument("--draft-w-bits", type=int, default=2,
                     choices=(1, 2, 3, 4),
                     help="draft model weight/activation precision (the "
                          "same checkpoint re-packed; only takes effect "
                          "on a packed engine)")
    eng.add_argument("--draft-kv-bits", type=int, default=-1,
                     choices=(-1, 0, 16, 8, 4, 2),
                     help="draft KV-cache precision override (-1 = "
                          "inherit the target's kv_bits)")

    samp = ap.add_argument_group("sampling")
    samp.add_argument("--temperature", type=float, default=0.0,
                      help="0 = greedy")
    samp.add_argument("--top-k", type=int, default=0)

    quant = ap.add_argument_group("quantization")
    quant.add_argument("--kv-bits", type=int, default=-1,
                       choices=(-1, 0, 16, 8, 4, 2),
                       help="KV cache storage precision override: 0/16 = "
                            "bf16, 8 = int8, 4/2 = bit-dense packed words; "
                            "-1 keeps the arch config's value")

    par = ap.add_argument_group("parallelism")
    par.add_argument("--model-parallel", type=int, default=1,
                     help="tensor-parallel shards per replica: packed "
                          "weights column-parallel, KV cache sharded on "
                          "the kv-head axis (serve/shard.ShardPlan).  "
                          "Testable on CPU via XLA_FLAGS=--xla_force_"
                          "host_platform_device_count=N")

    fleet = ap.add_argument_group(
        "fleet", "replica fleet (serve/router.Router, DESIGN.md §17)")
    fleet.add_argument("--data-parallel", type=int, default=1,
                       help="replica count: serve over a ('data'=N, "
                            "'model'=M) mesh carved into N replica "
                            "groups behind one load-balanced router "
                            "(least-loaded placement, spillover, session "
                            "affinity, drain/restore)")
    return ap


def _fleet_main(args, cfg, params, econf: EngineConfig):
    from repro.launch.mesh import make_serving_mesh
    from repro.serve.router import Router

    mesh = make_serving_mesh(model=args.model_parallel,
                             data=args.data_parallel)
    router = Router(cfg, params, config=econf, mesh=mesh)
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        # alternate sessions so affinity pinning is visible in the report
        router.submit(
            rng.integers(0, cfg.vocab_size, args.prompt_len).astype(
                np.int32),
            max_new_tokens=args.max_new_tokens,
            session=f"session-{i % 2}")
    done = router.run_to_completion()
    rep = router.metrics_report()
    rep["capacity"] = router.capacity_report()
    toks = sum(len(h.output) for h in done)
    fleet = rep["fleet"]
    print(f"{len(done)} requests, {toks} generated tokens across "
          f"{fleet['attached']} replicas (mesh {dict(mesh.shape)})")
    if args.metrics:
        print(json.dumps(rep, indent=2))
    else:
        print(f"fleet prefill {fleet['prefill_tok_s']} tok/s, "
              f"decode {fleet['decode_tok_s']} tok/s, "
              f"ttft p95 {fleet['ttft_s']['p95']}s, "
              f"spilled {fleet['spilled']} "
              f"(--metrics for the full report)")


def main():
    args = build_parser().parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    if args.kv_bits >= 0:
        cfg = cfg.replace(quant=cfg.quant.replace(kv_bits=args.kv_bits))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    econf = EngineConfig.from_args(args)

    if args.data_parallel > 1:
        _fleet_main(args, cfg, params, econf)
        if args.autotune:
            from repro.kernels import autotune as autotune_lib
            print(f"autotune cache saved to "
                  f"{autotune_lib.active_cache().save()}")
        return

    mesh = None
    if args.model_parallel > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.model_parallel)
    eng = ServingEngine(cfg, params, config=econf, mesh=mesh)
    if args.autotune:
        from repro.kernels import autotune as autotune_lib
        print(f"autotune cache saved to "
              f"{autotune_lib.active_cache().save()}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(
                np.int32),
            max_new_tokens=args.max_new_tokens))
    done = eng.run_to_completion()
    rep = eng.metrics.report()
    rep["capacity"] = eng.capacity_report()
    toks = sum(len(r.output) for r in done)
    # report the ACTUAL shard count: make_serving_mesh clamps (with a
    # warning) when the host has fewer devices than --model-parallel asked
    # for, and labeling those numbers as N-way TP would misattribute them
    shards = eng.shard_plan.model_shards if eng.shard_plan else 1
    print(f"{len(done)} requests, {toks} generated tokens"
          + (f" (model-parallel x{shards})" if shards > 1 else ""))
    if args.metrics:
        print(json.dumps(rep, indent=2))
    else:
        print(f"prefill {rep['prefill_tok_s']} tok/s, "
              f"decode {rep['decode_tok_s']} tok/s, "
              f"ttft p50 {rep['ttft_s']['p50']}s, "
              f"tpot p50 {rep['tpot_s']['p50']}s "
              f"(--metrics for the full report)")


if __name__ == "__main__":
    main()

"""CLI server: pack a model for deployment and serve synthetic requests
through the continuous-batching engine (chunked prefill + ragged decode,
DESIGN.md §12).

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --reduced --requests 4 --prefill-chunk 16

Tensor-parallel serving (serve/shard.ShardPlan, DESIGN.md §15) on a
CPU-simulated mesh:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 PYTHONPATH=src \
        python -m repro.launch.serve --arch stablelm-1.6b --reduced \
        --model-parallel 4 --metrics
"""

from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models import lm
from repro.serve.engine import Request, SamplingParams, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ALL_NAMES)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=6)
    ap.add_argument("--max-new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=16)
    ap.add_argument("--max-queue", type=int, default=0,
                    help="backpressure cap on queued requests (0 = none)")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="0 = greedy")
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--no-packed", action="store_true")
    ap.add_argument("--autotune", action="store_true",
                    help="warm-tune the serving kernel signatures missing "
                         "from the autotune cache before planning, then "
                         "persist the cache (tune once offline; plans come "
                         "back cache-backed on later launches)")
    ap.add_argument("--kv-bits", type=int, default=-1,
                    choices=(-1, 0, 16, 8, 4, 2),
                    help="KV cache storage precision override: 0/16 = bf16, "
                         "8 = int8, 4/2 = bit-dense packed words; -1 keeps "
                         "the arch config's value")
    ap.add_argument("--hbm-cache-budget-mb", type=float, default=0,
                    help="size batch slots from this HBM cache budget "
                         "(slots = budget // cache bytes per slot) instead "
                         "of --max-batch")
    ap.add_argument("--model-parallel", type=int, default=1,
                    help="tensor-parallel shards: serve over a ('data'=1, "
                         "'model'=N) mesh — packed weights column-parallel, "
                         "KV cache sharded on the kv-head axis (serve/"
                         "shard.ShardPlan).  Testable on CPU via "
                         "XLA_FLAGS=--xla_force_host_platform_device_"
                         "count=N")
    ap.add_argument("--metrics", action="store_true",
                    help="print the full engine metrics report (throughput "
                         "split by phase, occupancy, per-request TTFT and "
                         "time-per-output-token mean/p50/p95) plus the "
                         "capacity/shard report as JSON")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, reduced=args.reduced)
    if args.kv_bits >= 0:
        cfg = cfg.replace(quant=cfg.quant.replace(kv_bits=args.kv_bits))
    params = lm.init_params(jax.random.PRNGKey(0), cfg)
    mesh = None
    if args.model_parallel > 1:
        from repro.launch.mesh import make_serving_mesh
        mesh = make_serving_mesh(args.model_parallel)
    eng = ServingEngine(
        cfg, params, max_batch=args.max_batch, max_len=args.max_len,
        packed=not args.no_packed, prefill_chunk=args.prefill_chunk,
        max_queue=args.max_queue or None,
        sampling=SamplingParams(temperature=args.temperature,
                                top_k=args.top_k),
        hbm_cache_budget=int(args.hbm_cache_budget_mb * 2**20) or None,
        autotune=args.autotune, mesh=mesh)
    if args.autotune:
        from repro.kernels import autotune as autotune_lib
        print(f"autotune cache saved to "
              f"{autotune_lib.active_cache().save()}")
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).astype(
                np.int32),
            max_new_tokens=args.max_new_tokens))
    done = eng.run_to_completion()
    rep = eng.metrics.report()
    rep["capacity"] = eng.capacity_report()
    toks = sum(len(r.output) for r in done)
    # report the ACTUAL shard count: make_serving_mesh clamps (with a
    # warning) when the host has fewer devices than --model-parallel asked
    # for, and labeling those numbers as N-way TP would misattribute them
    shards = eng.shard_plan.model_shards if eng.shard_plan else 1
    print(f"{len(done)} requests, {toks} generated tokens"
          + (f" (model-parallel x{shards})" if shards > 1 else ""))
    if args.metrics:
        print(json.dumps(rep, indent=2))
    else:
        print(f"prefill {rep['prefill_tok_s']} tok/s, "
              f"decode {rep['decode_tok_s']} tok/s, "
              f"ttft p50 {rep['ttft_s']['p50']}s, "
              f"tpot p50 {rep['tpot_s']['p50']}s "
              f"(--metrics for the full report)")


if __name__ == "__main__":
    main()

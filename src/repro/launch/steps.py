"""Step factories: train_step / prefill_step / decode_step.

These are the functions the dry-run lowers and the trainer/server jit.
Quantization modes per step kind (DESIGN.md §2, §5, §12):
  train         -> 'qat'    (LSQ fake-quant, STE grads)
  prefill       -> 'qat'    (compute-bound; on TPU the fused Pallas kernel
                             serves this role — the CPU-lowered dry-run
                             uses fake-quant)
  prefill_chunk -> 'packed' (serving-time chunked prefill over the engine's
                             packed params: [B, chunk] windows per slot at
                             batched arithmetic intensity)
  decode        -> 'packed' (the deployed Sparq integer path; scan-free
                             batched packed dots so roofline FLOPs are
                             exact)

The decode and prefill_chunk steps are cache-template-agnostic: the engine
passes whatever layout ``cfg.quant.kv_bits`` selected (bf16 / int8 /
bit-dense packed words + scales, lm.init_caches), and attention fuses the
unpack+dequant of quantized templates into its q-chunked loop — the jitted
step never materializes a full-precision cache (DESIGN.md §13).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import ulppack_attention
from repro.models import lm
from repro.optim import adamw, schedules


def quant_mode_for(cfg, kind: str) -> str:
    if not cfg.quant.enabled:
        return "none"
    return {"train": "qat", "prefill": "qat", "prefill_chunk": "packed",
            "decode": "packed"}[kind]


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------

def make_train_step(cfg, *, adamw_cfg: adamw.AdamWConfig | None = None,
                    schedule: str = "cosine", peak_lr: float = 3e-4,
                    warmup_steps: int = 100, total_steps: int = 10_000,
                    clip_norm: float = 1.0, compress_grads: bool = False):
    adamw_cfg = adamw_cfg or adamw.AdamWConfig(
        eightbit_moments=cfg.parallel.eightbit_moments)
    sched = schedules.get_schedule(schedule)
    qmode = quant_mode_for(cfg, "train")
    remat = cfg.parallel.remat != "none"
    n_micro = max(1, cfg.parallel.microbatches)

    def loss_of(params, mb):
        logits, aux, _ = lm.forward(params, cfg, mb, quant_mode=qmode,
                                    remat=remat)
        loss, ce = lm.loss_fn(logits, mb["labels"], aux)
        return loss, ce

    grad_fn = jax.value_and_grad(loss_of, has_aux=True)

    def split_micro(batch):
        def sp(x):
            if x.ndim >= 2 and x.shape[0] == 3:      # positions3 [3,B,S]
                return jnp.moveaxis(
                    x.reshape(3, n_micro, x.shape[1] // n_micro,
                              *x.shape[2:]), 1, 0)
            return x.reshape(n_micro, x.shape[0] // n_micro, *x.shape[1:])
        return jax.tree.map(sp, batch)

    def train_step(state, batch):
        params, opt_state, step = (state["params"], state["opt_state"],
                                   state["step"])
        lr = sched(step, peak_lr=peak_lr, warmup_steps=warmup_steps,
                   total_steps=total_steps)

        if n_micro == 1:
            (loss, ce), grads = grad_fn(params, batch)
        else:
            micro = split_micro(batch)

            from repro.parallel.sharding import constrain_like_params

            def body(acc, mb):
                (l, c), g = grad_fn(params, mb)
                g_acc, l_acc, c_acc = acc
                g_new = constrain_like_params(
                    jax.tree.map(jnp.add, g_acc, g), cfg)
                return (g_new, l_acc + l, c_acc + c), None

            zeros = constrain_like_params(jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params), cfg)
            (grads, loss, ce), _ = jax.lax.scan(
                body, (zeros, 0.0, 0.0), micro)
            grads = jax.tree.map(lambda g: g / n_micro, grads)
            loss, ce = loss / n_micro, ce / n_micro

        if compress_grads:
            from repro.parallel import collectives
            grads, state = collectives.compress_grads_with_feedback(
                grads, state)

        grads, gnorm = adamw.clip_by_global_norm(grads, clip_norm)
        updates, opt_state = adamw.update(grads, opt_state, params, lr,
                                          adamw_cfg)
        params = adamw.apply_updates(params, updates)
        new_state = dict(state)
        new_state.update(params=params, opt_state=opt_state, step=step + 1)
        metrics = {"loss": loss, "ce": ce, "grad_norm": gnorm, "lr": lr}
        return new_state, metrics

    return train_step


def make_train_state(params, adamw_cfg: adamw.AdamWConfig | None = None,
                     error_feedback: bool = False, cfg=None):
    if adamw_cfg is None:
        adamw_cfg = adamw.AdamWConfig(
            eightbit_moments=cfg.parallel.eightbit_moments if cfg is not None
            else False)
    state = {"params": params,
             "opt_state": adamw.init(params, adamw_cfg),
             "step": jnp.zeros((), jnp.int32)}
    if error_feedback:
        state["error_feedback"] = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return state


# ---------------------------------------------------------------------------
# Serve
# ---------------------------------------------------------------------------

def make_prefill_step(cfg, max_len: int):
    qmode = quant_mode_for(cfg, "prefill")

    def prefill_step(params, batch):
        from repro.models import common as _c
        b = batch["tokens"].shape[0]
        caches = lm.init_caches(cfg, b, max_len,
                                dtype=_c.dtype_of(cfg.compute_dtype))
        logits, _, caches = lm.forward(params, cfg, batch,
                                       quant_mode=qmode, caches=caches)
        return logits[:, -1], caches

    return prefill_step


def make_decode_step(cfg, *, kv_shard_axis: str | None = None):
    """Single-token decode step.

    ``index`` scalar = lockstep (all rows share one position, the legacy
    path); ``index`` [B] = per-slot positions for ragged continuous
    batching, with optional ``valid`` [B] (1 = live slot, 0 = dead slot:
    no cache write, output ignored).  See DESIGN.md §12.

    ``kv_shard_axis`` names the mesh axis the serving ShardPlan sharded
    the KV-cache kv-head axis over (None = single-device serving); the
    attention write path constrains its quantize/pack/scatter to stay
    head-local on that axis (DESIGN.md §15).

    A paged engine additionally passes ``block_tables`` [B, n_pages]
    (host-side numpy, replicated under a mesh) and paged pool caches;
    omitting it keeps the slot-contiguous path byte-for-byte unchanged
    (DESIGN.md §18).
    """
    qmode = quant_mode_for(cfg, "decode")

    def decode_step(params, caches, batch, index, valid=None,
                    block_tables=None):
        b = batch["tokens"].shape[0]
        dec = dict(batch)
        idx = jnp.asarray(index, jnp.int32)
        if idx.ndim == 0:
            dec["positions"] = jnp.full((b, 1), idx, jnp.int32)
        else:
            dec["positions"] = idx[:, None]
        logits, _, caches = lm.forward(params, cfg, dec, quant_mode=qmode,
                                       caches=caches, cache_index=idx,
                                       cache_valid=valid,
                                       kv_shard_axis=kv_shard_axis,
                                       block_tables=block_tables)
        return logits[:, -1], caches

    return decode_step


def make_prefill_chunk_step(cfg, *, kv_shard_axis: str | None = None):
    """Chunked-prefill step: consumes a [B, chunk] token window per slot.

    ``index`` [B] is each slot's write offset (tokens already in its cache
    row); ``valid`` [B] is how many of the window's tokens are real (valid-
    prefix; 1 lets a decode-phase slot ride along with its single pending
    token, 0 = dead slot).  Runs the deployed packed path so admission cost
    is O(prompt_len / chunk) launches at batched arithmetic intensity
    instead of O(prompt_len) batch-1 decode steps (DESIGN.md §12).
    Returns (last-valid-token logits [B, vocab], new caches).
    """
    qmode = quant_mode_for(cfg, "prefill_chunk")

    def prefill_chunk_step(params, caches, batch, index, valid,
                           block_tables=None):
        b, c = batch["tokens"].shape
        dec = dict(batch)
        idx = jnp.asarray(index, jnp.int32)
        vld = jnp.asarray(valid, jnp.int32)
        dec["positions"] = idx[:, None] + jnp.arange(c, dtype=jnp.int32)
        logits, _, caches = lm.forward(params, cfg, dec, quant_mode=qmode,
                                       caches=caches, cache_index=idx,
                                       cache_valid=vld,
                                       kv_shard_axis=kv_shard_axis,
                                       block_tables=block_tables)
        last = jnp.clip(vld - 1, 0, c - 1)
        return (jnp.take_along_axis(logits, last[:, None, None],
                                    axis=1)[:, 0], caches)

    return prefill_chunk_step


def make_verify_chunk_step(cfg, *, kv_shard_axis: str | None = None):
    """Speculative-verify step: a prefill-chunk pass returning the FULL
    per-position logits window (DESIGN.md §19).

    Identical cache semantics to :func:`make_prefill_chunk_step` — the
    [B, w] window writes K/V at per-slot offsets ``index`` with
    valid-prefix gating ``valid`` — but returns ``logits [B, w, vocab]``
    instead of only the last valid row: window row ``j`` is the target
    distribution for the token at position ``index + j + 1``, exactly
    what accept/reject needs for every drafted token at once.  Chunked
    writes equal sequential writes (the PR 2 invariant), so positions
    past the accepted prefix hold stale K/V that attention masks (via
    ``cache_valid``-derived visibility) until a later pass overwrites
    them — speculative rollback is simply not advancing the slot
    position.
    """
    qmode = quant_mode_for(cfg, "prefill_chunk")

    def verify_chunk_step(params, caches, batch, index, valid,
                          block_tables=None):
        b, c = batch["tokens"].shape
        dec = dict(batch)
        idx = jnp.asarray(index, jnp.int32)
        vld = jnp.asarray(valid, jnp.int32)
        dec["positions"] = idx[:, None] + jnp.arange(c, dtype=jnp.int32)
        logits, _, caches = lm.forward(params, cfg, dec, quant_mode=qmode,
                                       caches=caches, cache_index=idx,
                                       cache_valid=vld,
                                       kv_shard_axis=kv_shard_axis,
                                       block_tables=block_tables)
        return logits, caches

    return verify_chunk_step


def make_draft_step(cfg, k: int, *, kv_shard_axis: str | None = None):
    """Draft ``k`` greedy tokens per slot in ONE device launch.

    ``cfg`` is the DRAFT model config (same checkpoint re-packed at
    ``draft_w_bits``, serve/speculative.draft_model_config).  The body
    unrolls ``k + 1`` single-token decode forwards (k is small and
    static): step ``i`` feeds token ``i`` of the chain (the slot's last
    committed token at i=0, then each argmax draft) at position
    ``index + i`` and writes its K/V row; steps ``0..k-1`` also argmax
    the next draft token.  The extra ``k``-th forward exists purely for
    its cache write — when every draft is accepted the next cycle needs
    the K/V of the last drafted token in the draft cache too.

    ``limit`` [B] caps per-slot drafting (``min(k, remaining - 1)``):
    step ``i`` writes its row iff ``i < limit + 1``, so draft-cache
    writes never exceed the slot's reserved extent.  Draft sampling is
    deliberately greedy (a delta proposal): the host-side rejection rule
    then needs only the TARGET distribution, keeping the draft launch
    RNG-free while the committed-token distribution still exactly
    matches target-only sampling (DESIGN.md §19).

    Returns (draft_tokens [B, k] int32, new draft caches); entries past
    ``limit`` are garbage the host ignores.
    """
    qmode = quant_mode_for(cfg, "decode")

    def draft_step(params, caches, batch, index, limit, block_tables=None):
        idx = jnp.asarray(index, jnp.int32)
        lim = jnp.asarray(limit, jnp.int32)
        tok = jnp.asarray(batch["tokens"][:, 0], jnp.int32)
        drafted = []
        for i in range(k + 1):
            dec = {"tokens": tok[:, None],
                   "positions": (idx + i)[:, None]}
            step_valid = (lim + 1 > i).astype(jnp.int32)
            logits, _, caches = lm.forward(
                params, cfg, dec, quant_mode=qmode, caches=caches,
                cache_index=idx + i, cache_valid=step_valid,
                kv_shard_axis=kv_shard_axis, block_tables=block_tables)
            if i < k:
                # vocab padding is already masked by forward's pad_bias,
                # so the argmax stays inside the real vocab
                tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
                drafted.append(tok)
        return jnp.stack(drafted, axis=1), caches

    return draft_step


def jitted_serving_steps(cfg, *, kv_shard_axis: str | None = None,
                         mesh=None):
    """Jitted ``(decode_step, prefill_chunk_step)`` pair, memoized per
    (model config, TP axis, mesh device set).

    A replica fleet (serve/router.Router) builds N ``ServingEngine``
    instances over ONE model config; without memoization each engine
    creates fresh ``jax.jit`` wrappers and re-pays trace + compile N
    times for identical computations.  Sharing the wrapper lets
    layout-identical replicas (same config, same — or no — mesh) reuse
    one executable.  The mesh's device ids are part of the key because
    jit executables bake in device placement: replicas on disjoint
    device groups must NOT share a wrapper, or the first replica's
    trace-time ``activation_mesh`` would leak into the others.
    """
    key = None if mesh is None else (
        tuple(d.id for d in mesh.devices.flat),
        tuple(sorted(mesh.shape.items())))
    return _jitted_serving_steps(cfg, kv_shard_axis, key,
                                 ulppack_attention.enabled())


@functools.lru_cache(maxsize=None)
def _jitted_serving_steps(cfg, kv_shard_axis, _mesh_key, _fused):
    # caches (arg 1) are donated: every engine call site reassigns its
    # cache pytree from the step's return, so the old buffers are dead on
    # entry and XLA may update the ring in place (DESIGN.md §20).  _fused
    # keys the memo on the REPRO_FUSED_DECODE kill-switch, which is read
    # at trace time — without it a flipped env var would hit stale traces.
    return (jax.jit(make_decode_step(cfg, kv_shard_axis=kv_shard_axis),
                    donate_argnums=(1,)),
            jax.jit(make_prefill_chunk_step(cfg,
                                            kv_shard_axis=kv_shard_axis),
                    donate_argnums=(1,)))


def jitted_speculative_steps(cfg, draft_cfg, k: int, *,
                             kv_shard_axis: str | None = None, mesh=None):
    """Jitted ``(draft_step, verify_chunk_step)`` pair for speculative
    decoding (DESIGN.md §19), memoized like :func:`jitted_serving_steps`.

    The draft step is keyed by the DRAFT config and ``k`` (its unroll
    depth is baked into the trace); the verify step by the TARGET config
    — so a fleet of replicas sharing one (target, draft, k) triple
    compiles each exactly once, and an engine whose target config
    already has serving steps shares nothing incorrectly (the verify
    window width is dynamic per trace, like prefill chunks).
    """
    key = None if mesh is None else (
        tuple(d.id for d in mesh.devices.flat),
        tuple(sorted(mesh.shape.items())))
    fused = ulppack_attention.enabled()
    return (_jitted_draft_step(draft_cfg, k, kv_shard_axis, key, fused),
            _jitted_verify_step(cfg, kv_shard_axis, key, fused))


@functools.lru_cache(maxsize=None)
def _jitted_draft_step(cfg, k, kv_shard_axis, _mesh_key, _fused):
    return jax.jit(make_draft_step(cfg, k, kv_shard_axis=kv_shard_axis),
                   donate_argnums=(1,))


@functools.lru_cache(maxsize=None)
def _jitted_verify_step(cfg, kv_shard_axis, _mesh_key, _fused):
    return jax.jit(make_verify_chunk_step(cfg,
                                          kv_shard_axis=kv_shard_axis),
                   donate_argnums=(1,))

"""Quantizers for sub-byte QNNs (paper §II-A context).

Supports the quantization families the paper builds on:
  * absmax / min-max affine calibration (post-training),
  * SAWB-style statistical weight scales [Choi et al.],
  * PACT-style learnable activation clipping,
  * LSQ learned-step-size fake-quant for QAT [Esser et al.],

All quantizers emit an *unsigned* lattice q in [0, 2^bits - 1] with affine
dequant  x ~= scale * (q - zero_point),  because ULPPACK packing requires
non-negative fields (DESIGN.md §4).  Weights use the midpoint zero-point
2^(bits-1) ("signed values on an unsigned lattice"); activations use either
z=0 (post-ReLU) or a calibrated/learned zero-point.
"""

from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class QuantConfig:
    """Quantization settings threaded through model configs."""

    w_bits: int = 4
    a_bits: int = 4
    enabled: bool = False
    # 'lsq' (QAT) or 'absmax' (PTQ) for weights; activations: 'lsq'|'minmax'.
    w_method: str = "lsq"
    a_method: str = "lsq"
    lane_dtype: str = "int16"   # packed lane for the inference kernel
    n_pack: int = 2
    # Field stride override for the packed lane (None -> lane default).  The
    # (lane_dtype, n_pack, pack_shift) triple names the *baseline* layout;
    # the autotuner may still pick a faster member of packing.LAYOUT_FAMILY
    # per layer (DESIGN.md §16).
    pack_shift: int | None = None
    # KV cache storage precision: 0 = bf16; 8 = int8 + per-(pos, kv-head)
    # bf16 scales; 4 | 2 = bit-dense packed int32 words (pack_words along
    # head_dim) + the same scale granularity (DESIGN.md §13).
    kv_bits: int = 0
    # Which projections to quantize.  Attention/S SM einsums always stay fp.
    quantize_lm_head: bool = False

    def __post_init__(self):
        if self.kv_bits not in (0, 2, 4, 8, 16):
            raise ValueError(
                f"kv_bits must be one of 0/16/8/4/2, got {self.kv_bits}")

    @property
    def qmax_w(self) -> int:
        return (1 << self.w_bits) - 1

    @property
    def qmax_a(self) -> int:
        return (1 << self.a_bits) - 1

    @property
    def w_zero_point(self) -> int:
        return 1 << (self.w_bits - 1)

    def replace(self, **kw) -> "QuantConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Affine lattice ops
# ---------------------------------------------------------------------------

def quantize_affine(x, scale, zero_point, bits):
    qmax = (1 << bits) - 1
    q = jnp.round(x / scale) + zero_point
    return jnp.clip(q, 0, qmax).astype(jnp.int32)


def dequantize_affine(q, scale, zero_point):
    return (q.astype(jnp.float32) - zero_point) * scale


def calibrate_absmax(x, bits, symmetric=True):
    """absmax scale; midpoint zero-point when symmetric (weights).

    Symmetric targets ``qmax - zp`` steps above the midpoint (NOT ``zp``:
    that would send ``+amax`` to ``2^bits``, one past ``qmax``, and the clip
    in ``quantize_affine`` would flatten the largest-magnitude weights by a
    full step).  ``-amax`` then lands at ``2*zp - qmax >= 0``, inside the
    lattice.
    """
    amax = jnp.max(jnp.abs(x))
    amax = jnp.maximum(amax, 1e-8)
    qmax = (1 << bits) - 1
    if symmetric:
        zp = 1 << (bits - 1)
        # max(.., 1) keeps bits=1 finite (qmax == zp there: the degenerate
        # {-amax, 0} lattice, matching the pre-fix behaviour)
        scale = amax / max(qmax - zp, 1)
    else:
        zp = 0
        scale = amax / qmax
    return scale, zp


def calibrate_minmax(x, bits):
    """Asymmetric min/max calibration (activations with negative support)."""
    lo = jnp.minimum(jnp.min(x), 0.0)
    hi = jnp.maximum(jnp.max(x), 1e-8)
    qmax = (1 << bits) - 1
    scale = (hi - lo) / qmax
    zp = jnp.clip(jnp.round(-lo / scale), 0, qmax)
    return scale, zp


def sawb_scale(w, bits):
    """SAWB statistical scale from E|w|, sqrt(E w^2) (paper ref [3]).

    Coefficients regressed in the SAWB paper for 2..8 bits; outside that we
    fall back to absmax.
    """
    coeffs = {2: (3.12, -2.064), 3: (7.509, -6.892), 4: (12.68, -12.80),
              5: (17.74, -18.64), 6: (22.80, -24.48), 7: (27.86, -30.32),
              8: (32.92, -36.16)}
    if bits not in coeffs:
        return calibrate_absmax(w, bits, symmetric=True)[0]
    c1, c2 = coeffs[bits]
    e1 = jnp.mean(jnp.abs(w))
    e2 = jnp.sqrt(jnp.mean(w * w))
    alpha = c1 * e2 + c2 * e1            # clip range
    zp = 1 << (bits - 1)
    return jnp.maximum(alpha, 1e-8) / zp


# ---------------------------------------------------------------------------
# Fake-quant with straight-through estimators (QAT forward path)
# ---------------------------------------------------------------------------

@partial(jax.custom_vjp, nondiff_argnums=(3,))
def fake_quant(x, scale, zero_point, bits):
    q = quantize_affine(x, scale, zero_point, bits)
    return dequantize_affine(q, scale, zero_point)


def _fq_fwd(x, scale, zero_point, bits):
    y = fake_quant(x, scale, zero_point, bits)
    return y, (x, scale, zero_point)


def _fq_bwd(bits, res, g):
    x, scale, zp = res
    qmax = (1 << bits) - 1
    lo = (0 - zp) * scale
    hi = (qmax - zp) * scale
    in_range = (x >= lo) & (x <= hi)
    dx = jnp.where(in_range, g, 0.0)
    # scale/zp treated as calibration constants here (no grad); LSQ below
    # provides the learned-scale path.
    return dx, jnp.zeros_like(scale), jnp.zeros_like(zp)


fake_quant.defvjp(_fq_fwd, _fq_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def lsq_fake_quant(x, step, bits, signed_midpoint):
    """LSQ fake-quant: learned step size with the LSQ gradient.

    signed_midpoint=True places the zero-point at 2^(bits-1) (weights);
    False uses z=0 (non-negative activations).
    """
    zp = (1 << (bits - 1)) if signed_midpoint else 0
    qmax = (1 << bits) - 1
    v = x / step + zp
    q = jnp.clip(jnp.round(v), 0, qmax)
    return (q - zp) * step


def _lsq_fwd(x, step, bits, signed_midpoint):
    y = lsq_fake_quant(x, step, bits, signed_midpoint)
    return y, (x, step)


def _lsq_bwd(bits, signed_midpoint, res, g):
    x, step = res
    zp = (1 << (bits - 1)) if signed_midpoint else 0
    qmax = (1 << bits) - 1
    v = x / step + zp
    q = jnp.round(v)
    below, above = v < 0, v > qmax
    mid = ~(below | above)
    dx = jnp.where(mid, g, 0.0)
    # d(out)/d(step): (q - zp) - (v - zp) inside the range; clip values at
    # the rails contribute (rail - zp).
    dstep_elem = jnp.where(
        mid, (q - v),
        jnp.where(below, (0 - zp), (qmax - zp)).astype(x.dtype))
    # LSQ gradient scale: 1/sqrt(numel * qmax) stabilizes step learning.
    gscale = 1.0 / jnp.sqrt(jnp.asarray(x.size, jnp.float32) * qmax)
    dstep = jnp.sum((g * dstep_elem).astype(jnp.float32)) * gscale
    return dx, jnp.reshape(dstep, jnp.shape(step)).astype(step.dtype)


lsq_fake_quant.defvjp(_lsq_fwd, _lsq_bwd)


@partial(jax.custom_vjp, nondiff_argnums=(2,))
def pact_clip(x, alpha, bits):
    """PACT: learnable upper clip for non-negative activations."""
    del bits
    return jnp.clip(x, 0.0, alpha)


def _pact_fwd(x, alpha, bits):
    return pact_clip(x, alpha, bits), (x, alpha)


def _pact_bwd(bits, res, g):
    del bits
    x, alpha = res
    dx = jnp.where((x > 0) & (x < alpha), g, 0.0)
    dalpha = jnp.sum(jnp.where(x >= alpha, g, 0.0))
    return dx, jnp.reshape(dalpha, jnp.shape(alpha))


pact_clip.defvjp(_pact_fwd, _pact_bwd)


def init_step_from_data(x, bits, signed_midpoint):
    """LSQ init: 2*E|x| / sqrt(qmax) (Esser et al. §3)."""
    qmax = (1 << bits) - 1
    denom = jnp.sqrt(jnp.asarray(float(qmax)))
    zp_span = (1 << (bits - 1)) if signed_midpoint else qmax
    del zp_span
    return jnp.maximum(2.0 * jnp.mean(jnp.abs(x)) / denom, 1e-6)

"""ULPPACK operand-packing algebra (paper §III-B) adapted to TPU integer lanes.

The "P1" packing scheme packs ``n_pack`` unsigned sub-byte operands into one
wider integer lane with field stride ``2**shift``.  A single wide multiply of an
activation lane against a *field-reversed* weight lane produces a product whose
middle bit-field holds the ``n_pack``-term dot-product contribution:

  n_pack=2:  (a0 + 2^S a1) * (w1 + 2^S w0)
               = a0*w1 + 2^S * (a0*w0 + a1*w1) + 2^2S * a1*w0
                 `-L-'         `-----D------'           `-H-'

Extraction of D from an s32 accumulation of such products is exact iff the
accumulated L stays below 2^S (no carry into D) and the accumulated D stays
below 2^S (no overflow into H).  ``k_tile_bound`` returns the largest number of
packed lanes that can be accumulated before an extraction is required — the
TPU analogue of the paper's "local accumulation" bound, and the quantity the
``vmacsr`` fused shift relaxes (see core/vmacsr.py and kernels/).

All packing here operates on *unsigned* integer lattices stored in signed
dtypes (int8/int16/int32); quantizers (core/quant.py) guarantee value ranges.
"""

from __future__ import annotations

import dataclasses
import re
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

# Lane dtype -> default field shift S for 2-way packing (field width = S bits).
LANE_SHIFT = {jnp.int8.dtype: 4, jnp.int16.dtype: 8, jnp.int32.dtype: 16}

# Signed-lane headroom: packed value must stay <= max of the *signed* lane
# dtype (the MXU consumes signed integers).
LANE_MAX = {jnp.int8.dtype: 127, jnp.int16.dtype: 32767, jnp.int32.dtype: 2**31 - 1}

# The candidate lane-layout family the autotuner sweeps: every structurally
# valid (lane_dtype, n_pack, shift) triple with byte-friendly field strides.
# Which members are *feasible* depends on (w_bits, a_bits) — see
# :func:`layout_family`.  The int16 P2/s8 entry is the config default.
LAYOUT_FAMILY = (
    ("int8", 2, 4),
    ("int16", 2, 8),     # default P1/P2 layout
    ("int16", 4, 4),     # binary P4 extension
    ("int32", 2, 8),
    ("int32", 2, 16),    # wide fields: huge k_tile, fewest extractions
    ("int32", 4, 8),
)


def _family_str() -> str:
    return ", ".join(f"{lane}xP{n}s{s}" for lane, n, s in LAYOUT_FAMILY)


@dataclasses.dataclass(frozen=True)
class PackSpec:
    """Static description of a packing configuration.

    Attributes:
      w_bits / a_bits: weight / activation precision (unsigned lattice width).
      lane_dtype:      integer dtype of the packed lane fed to the MXU.
      n_pack:          operands per lane (2, or 4 for the P4 extension).
      shift:           field stride in bits (None -> lane default: LANE_SHIFT
                       for n_pack=2, lane_bits/4 for n_pack=4).

    Construction validates *structure* only (lane dtype, n_pack, field span);
    whether a given (w_bits, a_bits) pair fits the layout overflow-free is the
    separate :attr:`feasible` predicate, so infeasible specs stay inspectable
    (Fig. 5 region tables).  Config-level entry points (:meth:`from_config`,
    the planners) reject infeasible specs outright.
    """

    w_bits: int
    a_bits: int
    lane_dtype: jnp.dtype = jnp.int16.dtype
    n_pack: int = 2
    shift: int | None = None

    def __post_init__(self):
        object.__setattr__(self, "lane_dtype", jnp.dtype(self.lane_dtype))
        if self.lane_dtype not in LANE_SHIFT:
            raise ValueError(
                f"lane_dtype must be one of int8/int16/int32, got "
                f"{self.lane_dtype}; supported layout family: {_family_str()}")
        lane_bits = 8 * self.lane_dtype.itemsize
        if self.n_pack not in (2, 4):
            raise ValueError(
                f"n_pack must be 2 or 4, got {self.n_pack}; supported layout "
                f"family: {_family_str()}")
        if self.shift is None:
            default = (LANE_SHIFT[self.lane_dtype] if self.n_pack == 2
                       else lane_bits // 4)
            object.__setattr__(self, "shift", default)
        if not isinstance(self.shift, int) or self.shift < 1:
            raise ValueError(
                f"shift must be a positive int, got {self.shift!r}; "
                f"supported layout family: {_family_str()}")
        if self.n_pack * self.shift > lane_bits:
            raise ValueError(
                f"{self.n_pack} fields of {self.shift} bits do not fit a "
                f"{lane_bits}-bit lane; supported layout family: "
                f"{_family_str()}")

    @classmethod
    def from_config(cls, qcfg) -> "PackSpec":
        """Build from a QuantConfig-like object (w_bits, a_bits, lane_dtype,
        n_pack, optional pack_shift) — the one blessed conversion, shared by
        every layer.  Raises at config time if the configured layout cannot
        hold the configured bit widths overflow-free."""
        spec = cls(qcfg.w_bits, qcfg.a_bits, jnp.dtype(qcfg.lane_dtype),
                   qcfg.n_pack, getattr(qcfg, "pack_shift", None))
        spec.validate()
        return spec

    def validate(self) -> "PackSpec":
        """Raise unless (w_bits, a_bits) is overflow-free under this layout."""
        if not self.feasible:
            raise ValueError(
                f"{self} is outside the overflow-free region: "
                f"k_tile_bound(w={self.w_bits}, a={self.a_bits}, "
                f"shift={self.shift}, n_pack={self.n_pack}) = {self.k_tile} "
                f"(need >= 1 and the packed value must fit the signed lane). "
                f"Feasible layouts for W{self.w_bits}A{self.a_bits}: "
                f"{[str(s) for s in layout_family(self.w_bits, self.a_bits)]}")
        return self

    @property
    def field_mask(self) -> int:
        return (1 << self.shift) - 1

    @property
    def max_w(self) -> int:
        return (1 << self.w_bits) - 1

    @property
    def max_a(self) -> int:
        return (1 << self.a_bits) - 1

    @property
    def k_tile(self) -> int:
        """Packed lanes accumulable before extraction (0 => infeasible)."""
        return k_tile_bound(self.w_bits, self.a_bits, self.shift, self.n_pack)

    @property
    def feasible(self) -> bool:
        return self.k_tile >= 1 and self.packed_value_fits

    @property
    def packed_value_fits(self) -> bool:
        """Does the largest packed operand fit the signed lane dtype?

        No product-magnitude bound is needed on top: s32 accumulation wraps
        mod 2^32, and bands strictly above the D band wrap harmlessly as long
        as the full packed layout spans <= 32 bits (``n_pack * shift <= 32``,
        guaranteed structurally).  Shift-mask extraction of D stays exact iff
        the L-carry and D-field constraints hold — that is ``k_tile_bound``,
        checked by :attr:`feasible` (DESIGN.md §16).
        """
        stride = 1 << self.shift
        weights = sum(stride**i for i in range(self.n_pack))
        biggest = max(self.max_w, self.max_a) * weights
        return biggest <= LANE_MAX[self.lane_dtype]

    def __str__(self):
        return (
            f"W{self.w_bits}A{self.a_bits}/{np.dtype(self.lane_dtype).name}"
            f"xP{self.n_pack}s{self.shift}"
        )

    _STR_RE = re.compile(
        r"^W(\d+)A(\d+)/(int8|int16|int32)xP(\d+)(?:s(\d+))?$")

    @classmethod
    def parse(cls, text: str) -> "PackSpec":
        """Inverse of ``str(spec)`` (used by the autotune layout cache).

        The shift suffix is optional for compatibility with pre-layout-sweep
        key strings; it then resolves to the lane default.
        """
        m = cls._STR_RE.match(text.strip())
        if not m:
            raise ValueError(
                f"cannot parse PackSpec from {text!r} "
                f"(expected e.g. 'W2A2/int16xP2s8')")
        w, a, lane, n, s = m.groups()
        return cls(int(w), int(a), jnp.dtype(lane), int(n),
                   int(s) if s is not None else None)


def k_tile_bound(w_bits: int, a_bits: int, shift: int, n_pack: int = 2) -> int:
    """Max packed lanes accumulable in s32 with exact shift-mask extraction.

    Two constraints (paper §III-B, adapted — see DESIGN.md §2):
      D-field:  sum of dot terms < 2^shift
      L-carry:  sum of everything below the D band < 2^(n_pack-1)*shift
    For n_pack=2 the D constraint binds (maxD = 2*maxL).  For n_pack=4 both are
    checked explicitly.
    """
    max_w = (1 << w_bits) - 1
    max_a = (1 << a_bits) - 1
    per_lane_d = n_pack * max_w * max_a
    if per_lane_d == 0:
        return 0
    field = (1 << shift) - 1
    k_d = field // per_lane_d
    # Everything strictly below the D band must not carry into it.  The D band
    # sits at bit (n_pack-1)*shift; bands below it are j-term cross products.
    low_per_lane = sum(
        (j + 1) * max_w * max_a * (1 << (shift * j)) for j in range(n_pack - 1)
    )
    low_cap = (1 << (shift * (n_pack - 1))) - 1
    k_l = low_cap // low_per_lane if low_per_lane else k_d
    return max(0, min(k_d, k_l))


def layout_family(w_bits: int, a_bits: int,
                  base: "PackSpec | None" = None) -> tuple:
    """Feasible candidate layouts for (w_bits, a_bits), ``base`` first.

    Every member packs/extracts bit-exactly (k_tile >= 1 and the packed value
    fits the signed lane), so the autotuner can sweep them freely — only
    overflow-free layouts are ever candidates.  ``base`` (the config-derived
    spec, when feasible) leads so ties resolve toward the default layout.
    """
    out = []
    if base is not None and base.feasible:
        out.append(base)
    for lane, n_pack, shift in LAYOUT_FAMILY:
        spec = PackSpec(w_bits, a_bits, jnp.dtype(lane), n_pack, shift)
        if spec.feasible and spec not in out:
            out.append(spec)
    return tuple(out)


def overflow_free_region(lane_dtype=jnp.int16.dtype, n_pack: int = 2,
                         max_bits: int = 8):
    """(w_bits, a_bits) -> k_tile table; reproduces paper Fig. 5 region shape."""
    table = {}
    for w in range(1, max_bits + 1):
        for a in range(1, max_bits + 1):
            spec = PackSpec(w, a, lane_dtype, n_pack)
            table[(w, a)] = spec.k_tile if spec.packed_value_fits else 0
    return table


def _as_lane(x, spec: PackSpec):
    return x.astype(spec.lane_dtype)


def pad_to_multiple(x: jax.Array, axis: int, multiple: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


def pack_activations(q: jax.Array, spec: PackSpec, axis: int = -1) -> jax.Array:
    """Pack unsigned activation lattice values along ``axis``.

    q[..., 2k] lands in the LOW field, q[..., 2k+1] in the HIGH field
    (ascending field order).  Input length along axis is padded to n_pack.
    """
    axis = axis % q.ndim
    q = pad_to_multiple(q.astype(jnp.int32), axis, spec.n_pack)
    new_shape = list(q.shape)
    new_shape[axis] //= spec.n_pack
    new_shape.insert(axis + 1, spec.n_pack)
    q = q.reshape(new_shape)
    packed = jnp.zeros(new_shape[:axis + 1] + new_shape[axis + 2:], jnp.int32)
    for j in range(spec.n_pack):
        field = jax.lax.index_in_dim(q, j, axis + 1, keepdims=False)
        packed = packed + (field << (spec.shift * j))
    return _as_lane(packed, spec)


def pack_weights(q: jax.Array, spec: PackSpec, axis: int = 0) -> jax.Array:
    """Pack unsigned weight lattice values along ``axis`` in REVERSED field
    order (P1 scheme) so the dot lands in the middle band."""
    axis = axis % q.ndim
    q = pad_to_multiple(q.astype(jnp.int32), axis, spec.n_pack)
    new_shape = list(q.shape)
    new_shape[axis] //= spec.n_pack
    new_shape.insert(axis + 1, spec.n_pack)
    q = q.reshape(new_shape)
    packed = jnp.zeros(new_shape[:axis + 1] + new_shape[axis + 2:], jnp.int32)
    for j in range(spec.n_pack):
        field = jax.lax.index_in_dim(q, j, axis + 1, keepdims=False)
        packed = packed + (field << (spec.shift * (spec.n_pack - 1 - j)))
    return _as_lane(packed, spec)


def unpack(packed: jax.Array, spec: PackSpec, axis: int = -1,
           reversed_fields: bool = False) -> jax.Array:
    """Inverse of pack_activations / pack_weights (for tests and debugging)."""
    axis = axis % packed.ndim
    p = packed.astype(jnp.int32)
    fields = []
    for j in range(spec.n_pack):
        pos = (spec.n_pack - 1 - j) if reversed_fields else j
        fields.append((p >> (spec.shift * pos)) & spec.field_mask)
    stacked = jnp.stack(fields, axis=axis + 1)
    shape = list(packed.shape)
    shape[axis] *= spec.n_pack
    return stacked.reshape(shape)


def pack_words(q: jax.Array, bits: int, axis: int = -1) -> jax.Array:
    """Bit-dense packing of an unsigned ``bits``-wide lattice along ``axis``.

    ``32 // bits`` values land per int32 word in ascending field order (for
    widths that don't divide 32, e.g. 3 bits -> 10 values, the top bits of
    the word stay unused); a non-dividing tail is zero-padded (callers
    record the true size and slice it back in :func:`unpack_words`).  This
    is the storage layout of the sub-byte KV cache (head-dim axis) and of
    the bit-dense weight store — true ``bits``/value HBM footprint, unlike
    P1 lanes which trade density for MXU-ready fields.
    """
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    per = 32 // bits
    axis = axis % q.ndim
    q = pad_to_multiple(q.astype(jnp.int32), axis, per)
    new_shape = list(q.shape)
    new_shape[axis] //= per
    new_shape.insert(axis + 1, per)
    q = q.reshape(new_shape)
    words = jnp.zeros(new_shape[:axis + 1] + new_shape[axis + 2:], jnp.int32)
    for j in range(per):
        field = jax.lax.index_in_dim(q, j, axis + 1, keepdims=False)
        words = words | (field << (bits * j))
    return words


def unpack_words(words: jax.Array, bits: int, size: int,
                 axis: int = -1) -> jax.Array:
    """Inverse of :func:`pack_words`: int32 words -> [..., size, ...] lattice
    values (s32) along ``axis``, dropping the zero-padded tail."""
    if not 1 <= bits <= 32:
        raise ValueError(f"bits must be in [1, 32], got {bits}")
    per = 32 // bits
    axis = axis % words.ndim
    mask = (1 << bits) - 1
    fields = [(words >> (bits * j)) & mask for j in range(per)]
    stacked = jnp.stack(fields, axis=axis + 1)
    shape = list(words.shape)
    shape[axis] *= per
    out = stacked.reshape(shape)
    if size == shape[axis]:
        return out
    return jax.lax.slice_in_dim(out, 0, size, axis=axis)


def extract_dot(acc32: jax.Array, spec: PackSpec) -> jax.Array:
    """Shift-mask extraction of the accumulated D band from s32 packed totals.

    Valid only if the number of accumulated packed lanes is <= spec.k_tile —
    tests assert tightness of that bound.
    """
    band = spec.shift * (spec.n_pack - 1)
    return (acc32 >> band) & spec.field_mask


def packed_dot_general(a_packed: jax.Array, w_packed: jax.Array,
                       spec: PackSpec) -> jax.Array:
    """One packed-tile contraction: [..., Kp] x [Kp, N] -> s32 packed totals.

    Caller must guarantee Kp <= spec.k_tile.  ``preferred_element_type=int32``
    keeps the MXU path exact.
    """
    return jax.lax.dot_general(
        a_packed, w_packed,
        dimension_numbers=(((a_packed.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


@partial(jax.jit, static_argnames=("spec",))
def packed_matmul_reference(q_a: jax.Array, q_w: jax.Array,
                            spec: PackSpec) -> jax.Array:
    """Full packed matmul at the XLA level ("native ULPPACK" path, no fusion).

    q_a: [M, K] unsigned activation lattice.  q_w: [K, N] unsigned weight
    lattice.  Returns the exact integer dot product [M, N] (s32), computed via
    packed tiles of k_tile lanes with extraction between tiles — the
    reproduction of ULPPACK running on stock Ara (paper Fig. 5a).
    """
    if not spec.feasible:
        raise ValueError(f"{spec} is outside the overflow-free region")
    a = pack_activations(q_a, spec, axis=-1)
    w = pack_weights(q_w, spec, axis=0)
    kp = a.shape[-1]
    kt = spec.k_tile
    n_tiles = -(-kp // kt)
    a = pad_to_multiple(a, -1, kt)
    w = pad_to_multiple(w, 0, kt)
    a_tiles = a.reshape(*a.shape[:-1], n_tiles, kt)
    w_tiles = w.reshape(n_tiles, kt, w.shape[-1])

    def body(carry, xs):
        a_t, w_t = xs
        packed_total = jax.lax.dot_general(
            a_t, w_t, (((a_t.ndim - 1,), (0,)), ((), ())),
            preferred_element_type=jnp.int32)
        return carry + extract_dot(packed_total, spec), None

    init = jnp.zeros((*q_a.shape[:-1], q_w.shape[-1]), jnp.int32)
    a_scan = jnp.moveaxis(a_tiles, -2, 0)
    out, _ = jax.lax.scan(body, init, (a_scan, w_tiles))
    return out

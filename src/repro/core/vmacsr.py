"""ISA-level emulation of Sparq's ``vmacsr`` instruction (paper §IV-A).

    vmacsr:  Vd <- Vd + ((Vs1 * Vs2) >> M)

These functions mirror the *hardware lane semantics* (fixed-width wraparound,
shift applied to the full-width SIMD product before accumulation) and exist
for three purposes:
  1. documentation-by-code of the instruction we are adapting,
  2. an instruction-count model used by benchmarks/fig4 (how many vector
     instructions each conv2d variant issues on Ara vs Sparq),
  3. unit tests tying the TPU kernel's per-tile extraction to the per-MAC
     semantics (they agree on the overflow-free region).

The *performance* realization on TPU is NOT this function — it is the fused
Pallas kernel (kernels/ulppack_matmul.py) whose epilogue plays the role of the
shifter; see DESIGN.md §2.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

_WIDE = {jnp.int8.dtype: jnp.int16, jnp.int16.dtype: jnp.int32,
         jnp.int32.dtype: jnp.int64}


def vmacc(vd, vs1, vs2):
    """RVV vmacc: vd += vs1*vs2, modulo lane width (low bits kept)."""
    lane = vd.dtype
    return (vd + vs1.astype(lane) * vs2.astype(lane)).astype(lane)


def vmacsr(vd, vs1, vs2, shift):
    """Sparq vmacsr: vd += (full-width(vs1*vs2) >> shift), modulo lane width.

    The SIMD multiplier internally produces the double-width product; the
    shifter (Fig. 2) sits between the multiplier and the accumulator, so the
    shift sees the FULL product — this is what kills the low cross-term before
    it can ever accumulate.
    """
    lane = jnp.dtype(vd.dtype)
    wide = _WIDE[lane]
    prod = vs1.astype(wide) * vs2.astype(wide)
    return (vd.astype(wide) + (prod >> shift)).astype(lane)


def vsrl(v, shift):
    """Logical shift right on unsigned-interpreted lanes."""
    lane = jnp.dtype(v.dtype)
    bits = lane.itemsize * 8
    mask = (1 << bits) - 1
    wide = _WIDE[lane]
    u = v.astype(wide) & mask
    return (u >> shift).astype(lane)


def vand(v, imm):
    return v & jnp.asarray(imm, v.dtype)


def vadd(a, b):
    return (a + b).astype(a.dtype)


# ---------------------------------------------------------------------------
# Instruction-count model (benchmarks/fig4): vector instructions per output
# tile of a packed dot product of K channels.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InstructionCount:
    macs: int          # vmacc / vmacsr issues
    shifts: int        # standalone vsrl issues
    masks: int         # vand issues
    adds: int          # vadd issues (wide accumulate after extraction)

    @property
    def total(self) -> int:
        return self.macs + self.shifts + self.masks + self.adds


def native_ulppack_instruction_count(k_channels: int, k_tile: int,
                                     n_pack: int = 2) -> InstructionCount:
    """Stock-Ara ULPPACK: vmacc per packed lane + extract every k_tile lanes."""
    lanes = -(-k_channels // n_pack)
    k_tile = max(k_tile, 1)
    extractions = -(-lanes // k_tile)
    return InstructionCount(macs=lanes, shifts=extractions,
                            masks=extractions, adds=extractions)


def vmacsr_instruction_count(k_channels: int, k_tile: int,
                             n_pack: int = 2) -> InstructionCount:
    """Sparq: vmacsr per packed lane; extraction collapses to a mask+add only
    at accumulator spill points (the fused shift removed the vsrl), and the
    relaxed constraint (no L-carry) doubles the spill distance."""
    lanes = -(-k_channels // n_pack)
    k_tile = max(2 * k_tile, 1)
    spills = -(-lanes // k_tile)
    return InstructionCount(macs=lanes, shifts=0, masks=spills, adds=spills)


def int16_instruction_count(k_channels: int) -> InstructionCount:
    """Baseline int16 dot product: one widening MAC per channel."""
    return InstructionCount(macs=k_channels, shifts=0, masks=0, adds=0)

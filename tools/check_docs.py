#!/usr/bin/env python
"""Docs link-checker: fail fast on doc rot (CI lint lane).

Checks, over the repo's markdown front doors (README.md, DESIGN.md,
reports/README.md):

* **Internal anchors** — every markdown link of the form
  ``[text](FILE.md#anchor)`` or ``[text](#anchor)`` must resolve to a
  heading in the target file under GitHub's slug rules (lowercase,
  punctuation stripped, spaces to hyphens — ``## §19 Speculative
  decoding`` -> ``#19-speculative-decoding...``).
* **Relative file links** — ``[text](path)`` must name a file or
  directory that exists in the checkout.
* **Backticked code paths** — any `` `a/b.py` ``-style token (must
  contain a ``/`` — bare filenames like ``BENCH_serve.json`` are often
  generated artifacts) must exist at the repo root or under ``src/``,
  ``src/repro/``, or ``.github/workflows/``.
* **DESIGN.md § citations** — every ``DESIGN.md §N`` reference in the
  checked docs AND in ``src/ tests/ benchmarks/ tools/`` sources must
  cite a section that exists (section numbers are stable, so a dangling
  citation means a typo, not a renumbering).

Run:  python tools/check_docs.py        (exit 0 clean, 1 with findings)
"""

from __future__ import annotations

import argparse
import os
import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = ("README.md", "DESIGN.md", "reports/README.md")
SOURCE_GLOBS = ("src/**/*.py", "tests/*.py", "benchmarks/*.py",
                "tools/*.py")
# roots tried, in order, when resolving a backticked code path
PATH_ROOTS = ("", "src", "src/repro", ".github/workflows")

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^(#{1,6})\s+(.*?)\s*$", re.M)
_BACKTICK_PATH = re.compile(r"`([\w][\w./\-]*/[\w.\-]+\.\w{1,6})`")
_SECTION_REF = re.compile(r"DESIGN\.md\s+§(\d+)")
_FENCE = re.compile(r"^```.*?^```", re.M | re.S)


def github_slug(heading: str) -> str:
    """GitHub's anchor slug: lowercase, drop punctuation (keeps word
    chars incl. unicode, spaces, hyphens), spaces -> hyphens."""
    s = re.sub(r"[^\w\- ]", "", heading.lower())
    return s.replace(" ", "-")


def heading_slugs(text: str) -> set[str]:
    slugs: set[str] = set()
    counts: dict[str, int] = {}
    for m in _HEADING.finditer(_FENCE.sub("", text)):
        base = github_slug(m.group(2))
        n = counts.get(base, 0)
        counts[base] = n + 1
        slugs.add(base if n == 0 else f"{base}-{n}")  # GitHub dedup rule
    return slugs


def design_sections(text: str) -> set[int]:
    return {int(m.group(1))
            for m in re.finditer(r"^##\s+§(\d+)\b", text, re.M)}


def check_doc(doc: Path, slug_cache: dict[Path, set[str]]) -> list[str]:
    problems: list[str] = []
    text = doc.read_text()
    rel = os.path.relpath(doc, ROOT)

    def slugs_of(path: Path) -> set[str]:
        if path not in slug_cache:
            slug_cache[path] = heading_slugs(path.read_text())
        return slug_cache[path]

    for m in _LINK.finditer(_FENCE.sub("", text)):
        target = m.group(1)
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, anchor = target.partition("#")
        base = doc.parent / path_part if path_part else doc
        if not base.exists():
            problems.append(f"{rel}: broken link target ({target})")
            continue
        if anchor and anchor not in slugs_of(base):
            problems.append(f"{rel}: broken anchor #{anchor} "
                            f"(no such heading in {path_part or rel})")

    for m in _BACKTICK_PATH.finditer(text):
        token = m.group(1)
        if not any((ROOT / r / token).exists() for r in PATH_ROOTS):
            roots = ", ".join(repr(r) for r in PATH_ROOTS)
            problems.append(f"{rel}: code path `{token}` does not exist "
                            f"(tried roots {roots})")
    return problems


def check_section_refs(files, sections: set[int]) -> list[str]:
    problems = []
    for f in files:
        for m in _SECTION_REF.finditer(f.read_text()):
            n = int(m.group(1))
            if n not in sections:
                problems.append(
                    f"{os.path.relpath(f, ROOT)}: cites DESIGN.md §{n} "
                    f"but DESIGN.md has no such section")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("docs", nargs="*", default=list(DOCS),
                    help="markdown files to check (default: %(default)s)")
    args = ap.parse_args(argv)

    slug_cache: dict[Path, set[str]] = {}
    problems: list[str] = []
    for name in args.docs:
        doc = (ROOT / name).resolve()
        if not doc.exists():
            problems.append(f"{name}: checked doc itself is missing")
            continue
        problems += check_doc(doc, slug_cache)

    sections = design_sections((ROOT / "DESIGN.md").read_text())
    sources = [p for g in SOURCE_GLOBS for p in sorted(ROOT.glob(g))]
    docs = [(ROOT / n) for n in args.docs if (ROOT / n).exists()]
    problems += check_section_refs(docs + sources, sections)

    for p in problems:
        print(f"DOC-ROT: {p}", file=sys.stderr)
    n_files = len(args.docs) + len(sources)
    print(f"check_docs: {len(problems)} problem(s) across "
          f"{n_files} file(s)")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
